//! Worker roster: addresses, byte budgets, health, and residency.
//!
//! The topology is the router's model of its backends. Each worker is
//! probed with `{"op":"ping"}` (liveness) and `{"op":"stats"}`
//! (residency: which variants are resident, how many packed bytes, what
//! byte budget and tuned policy the worker runs) — both side-effect-free
//! on the worker. A failed probe or a failed in-flight request marks the
//! worker **down**; the next successful probe marks it back **up**, so a
//! restarted backend rejoins the fleet without router intervention.
//!
//! [`WorkerClient`] is the one line-protocol client used everywhere the
//! router talks to a backend: request/response over one TCP connection,
//! with optional read/write timeouts so a stalled backend surfaces as an
//! error instead of wedging a router thread.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::server::{frames, Emit, EmitSink};
use crate::tune::TunedPolicy;
use crate::util::json::Json;

/// One `--worker` roster entry: `host:port` with an optional
/// operator-declared packed-byte budget (used for placement when the
/// worker itself reports an unbounded registry).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSpec {
    pub addr: String,
    pub budget: Option<usize>,
}

impl WorkerSpec {
    /// Parse `host:port` or `host:port:budget` (the repeatable CLI
    /// `--worker` format).
    pub fn parse(s: &str) -> Result<WorkerSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            [host, port] if !host.is_empty() && !port.is_empty() => {
                Ok(WorkerSpec { addr: s.to_string(), budget: None })
            }
            [host, port, budget] if !host.is_empty() && !port.is_empty() => Ok(WorkerSpec {
                addr: format!("{host}:{port}"),
                budget: Some(
                    budget.parse().map_err(|_| anyhow!("bad budget in worker spec {s:?}"))?,
                ),
            }),
            _ => bail!("bad worker spec {s:?} (want host:port or host:port:budget)"),
        }
    }
}

/// Roster-internal mutable state for one worker.
struct WorkerState {
    spec: WorkerSpec,
    up: bool,
    /// Full registry keys resident on this worker (probe + load/unload
    /// bookkeeping between probes).
    resident: HashSet<String>,
    resident_bytes: usize,
    /// Budget the worker itself reported (`stats.budget_bytes`);
    /// overrides the operator-declared spec budget when present.
    probed_budget: Option<usize>,
    policy_hash: Option<String>,
    policy_entries: usize,
    policy_source: Option<String>,
    last_error: Option<String>,
}

/// A read-only snapshot of one worker, handed to placement and routing
/// (no locks held while the router does I/O).
#[derive(Debug, Clone)]
pub struct WorkerView {
    pub id: usize,
    pub addr: String,
    pub up: bool,
    pub resident: HashSet<String>,
    pub resident_bytes: usize,
    /// Effective packed-byte budget: worker-reported, else the
    /// operator-declared roster budget, else unbounded.
    pub budget_bytes: Option<usize>,
    pub policy_hash: Option<String>,
    pub policy_entries: usize,
    pub policy_source: Option<String>,
    pub last_error: Option<String>,
}

impl WorkerView {
    /// Packed bytes this worker may still spend; unbounded workers
    /// report a huge-but-finite headroom so `max_by_key` ordering stays
    /// total.
    pub fn headroom(&self) -> usize {
        match self.budget_bytes {
            Some(b) => b.saturating_sub(self.resident_bytes),
            None => usize::MAX / 2,
        }
    }
}

/// What one probe round learned about a worker.
struct ProbeResult {
    resident: HashSet<String>,
    resident_bytes: usize,
    probed_budget: Option<usize>,
    policy_hash: Option<String>,
    policy_entries: usize,
    policy_source: Option<String>,
}

/// The shared worker roster. All mutation goes through `&self` (internal
/// mutex), so every router connection and the background prober share one
/// instance.
pub struct Topology {
    workers: Mutex<Vec<WorkerState>>,
    io_timeout: Option<Duration>,
}

impl Topology {
    pub fn new(specs: Vec<WorkerSpec>, io_timeout: Option<Duration>) -> Topology {
        let workers = specs
            .into_iter()
            .map(|spec| WorkerState {
                spec,
                // Workers start down; the first probe marks them up.
                up: false,
                resident: HashSet::new(),
                resident_bytes: 0,
                probed_budget: None,
                policy_hash: None,
                policy_entries: 0,
                policy_source: None,
                last_error: None,
            })
            .collect();
        Topology { workers: Mutex::new(workers), io_timeout }
    }

    pub fn len(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn addr_of(&self, id: usize) -> Result<String> {
        let w = self.workers.lock().unwrap();
        w.get(id)
            .map(|s| s.spec.addr.clone())
            .ok_or_else(|| anyhow!("no worker {id} in the roster"))
    }

    /// Snapshot of every worker for placement/routing decisions.
    pub fn snapshot(&self) -> Vec<WorkerView> {
        let w = self.workers.lock().unwrap();
        w.iter()
            .enumerate()
            .map(|(id, s)| WorkerView {
                id,
                addr: s.spec.addr.clone(),
                up: s.up,
                resident: s.resident.clone(),
                resident_bytes: s.resident_bytes,
                budget_bytes: s.probed_budget.or(s.spec.budget),
                policy_hash: s.policy_hash.clone(),
                policy_entries: s.policy_entries,
                policy_source: s.policy_source.clone(),
                last_error: s.last_error.clone(),
            })
            .collect()
    }

    /// Mark a worker down after a failed request or probe. Down workers
    /// stay in the roster and are re-probed; routing skips them.
    pub fn mark_down(&self, id: usize, err: &str) {
        let mut w = self.workers.lock().unwrap();
        if let Some(s) = w.get_mut(id) {
            if s.up {
                log::warn!("fleet: worker {} marked down: {err}", s.spec.addr);
            }
            s.up = false;
            s.last_error = Some(err.to_string());
        }
    }

    /// Whether the roster shows `key` resident on worker `id` — the
    /// hot-path check (`ensure_resident` runs it per scoring candidate)
    /// that must not clone a full snapshot.
    pub fn is_resident(&self, id: usize, key: &str) -> bool {
        let w = self.workers.lock().unwrap();
        w.get(id).is_some_and(|s| s.resident.contains(key))
    }

    /// Record a variant made resident on a worker (a routed `load`
    /// response, or test seeding) without waiting for the next probe.
    pub fn note_loaded(&self, id: usize, key: &str) {
        let mut w = self.workers.lock().unwrap();
        if let Some(s) = w.get_mut(id) {
            s.up = true;
            s.resident.insert(key.to_string());
        }
    }

    /// Record a routed `unload` so scatter routing stops targeting the
    /// worker before the next probe.
    pub fn note_unloaded(&self, id: usize, key: &str) {
        let mut w = self.workers.lock().unwrap();
        if let Some(s) = w.get_mut(id) {
            s.resident.remove(key);
        }
    }

    /// Worker ids currently marked up.
    pub fn up_ids(&self) -> Vec<usize> {
        let w = self.workers.lock().unwrap();
        w.iter().enumerate().filter(|(_, s)| s.up).map(|(id, _)| id).collect()
    }

    /// One probe round: ping + stats against every worker (up or down —
    /// a down worker answering is the mark-up path). With `push`, a
    /// worker whose policy fingerprint differs from the router policy
    /// gets `{"op":"policy","set":...}` before its state is recorded, so
    /// one probe round heals fleet-wide policy skew.
    pub fn probe_all(&self, push: Option<&TunedPolicy>) {
        // Probes answer in microseconds on a healthy worker; cap the
        // wait well below the serving io timeout so one dead address
        // does not stall the probe round.
        let t = Some(match self.io_timeout {
            Some(t) => t.min(Duration::from_secs(2)),
            None => Duration::from_secs(2),
        });
        let addrs: Vec<(usize, String)> = {
            let w = self.workers.lock().unwrap();
            w.iter().enumerate().map(|(id, s)| (id, s.spec.addr.clone())).collect()
        };
        // Probe concurrently: a round over N workers costs one probe's
        // wall clock, not N — dead addresses burn their connect timeout
        // in parallel instead of stretching the round past the probe
        // interval and delaying every other worker's mark-up.
        let probed: Vec<(usize, String, Result<ProbeResult>)> = std::thread::scope(|s| {
            let joins: Vec<_> = addrs
                .into_iter()
                .map(|(id, addr)| {
                    s.spawn(move || {
                        let r = probe_worker(&addr, t, push);
                        (id, addr, r)
                    })
                })
                .collect();
            joins
                .into_iter()
                .enumerate()
                .map(|(id, j)| {
                    j.join().unwrap_or_else(|_| {
                        (id, String::new(), Err(anyhow!("probe thread panicked")))
                    })
                })
                .collect()
        });
        for (id, addr, result) in probed {
            match result {
                Ok(r) => {
                    let mut w = self.workers.lock().unwrap();
                    if let Some(s) = w.get_mut(id) {
                        if !s.up {
                            log::info!("fleet: worker {addr} is up");
                        }
                        s.up = true;
                        s.resident = r.resident;
                        s.resident_bytes = r.resident_bytes;
                        s.probed_budget = r.probed_budget;
                        s.policy_hash = r.policy_hash;
                        s.policy_entries = r.policy_entries;
                        s.policy_source = r.policy_source;
                        s.last_error = None;
                    }
                }
                Err(e) => self.mark_down(id, &format!("probe failed: {e:#}")),
            }
        }
    }
}

/// Probe one worker over a fresh connection: ping, stats, and optionally
/// a policy push when the fingerprints differ.
fn probe_worker(
    addr: &str,
    timeout: Option<Duration>,
    push: Option<&TunedPolicy>,
) -> Result<ProbeResult> {
    let mut c = WorkerClient::connect(addr, timeout)?;
    let pong = c.request(&Json::obj(vec![("op", Json::str("ping"))]))?;
    if let Some(e) = pong.opt("error") {
        bail!("ping rejected: {}", e.as_str().unwrap_or("unknown error"));
    }
    let stats = c.request(&Json::obj(vec![("op", Json::str("stats"))]))?;
    if let Some(e) = stats.opt("error") {
        bail!("stats rejected: {}", e.as_str().unwrap_or("unknown error"));
    }
    let mut r = parse_stats(&stats)?;
    if let Some(policy) = push {
        let want = policy.fingerprint();
        if r.policy_hash.as_deref() != Some(want.as_str()) {
            let set = Json::obj(vec![
                ("op", Json::str("policy")),
                ("set", policy.to_json()),
            ]);
            match c.request(&set) {
                Ok(resp) if resp.opt("error").is_none() => {
                    log::info!("fleet: pushed policy {want} to {addr}");
                    r.policy_hash = Some(want);
                    r.policy_entries = policy.entries.len();
                    r.policy_source = None;
                }
                Ok(resp) => log::warn!(
                    "fleet: {addr} rejected policy push: {}",
                    resp.opt("error").and_then(|e| e.as_str().ok()).unwrap_or("?")
                ),
                Err(e) => log::warn!("fleet: policy push to {addr} failed: {e:#}"),
            }
        }
    }
    Ok(r)
}

/// Pull the roster-relevant fields out of a worker `{"op":"stats"}`
/// response (resident keys, total bytes, budget, policy identity).
fn parse_stats(stats: &Json) -> Result<ProbeResult> {
    let resident: HashSet<String> = stats
        .get("models")?
        .as_arr()?
        .iter()
        .map(|m| Ok(m.get("key")?.as_str()?.to_string()))
        .collect::<Result<_>>()?;
    let resident_bytes = stats.get("resident_bytes_total")?.as_usize()?;
    let probed_budget = match stats.get("budget_bytes")? {
        Json::Null => None,
        v => Some(v.as_usize()?),
    };
    let (policy_hash, policy_entries, policy_source) = match stats.opt("policy") {
        None | Some(Json::Null) => (None, 0, None),
        Some(p) => (
            Some(p.get("hash")?.as_str()?.to_string()),
            p.get("entries")?.as_usize()?,
            match p.get("source")? {
                Json::Null => None,
                v => Some(v.as_str()?.to_string()),
            },
        ),
    };
    Ok(ProbeResult {
        resident,
        resident_bytes,
        probed_budget,
        policy_hash,
        policy_entries,
        policy_source,
    })
}

/// A line-protocol client for one backend connection — request out,
/// response line(s) back. The router holds one per (client connection ×
/// worker) for request forwarding, plus short-lived ones for probes and
/// scatter blocks.
pub struct WorkerClient {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// This connection negotiated `bin1` score frames (see
    /// [`crate::server::frames`]); streamed chunk responses may arrive
    /// as binary frames and are passed through as [`Emit::Raw`].
    bin1: bool,
}

impl WorkerClient {
    /// Connect with an optional timeout applied to connect, read, and
    /// write — a stalled backend then errors out instead of blocking a
    /// router thread forever.
    pub fn connect(addr: &str, timeout: Option<Duration>) -> Result<WorkerClient> {
        let stream = match timeout {
            Some(t) => {
                let sa = addr
                    .to_socket_addrs()
                    .with_context(|| format!("resolving worker {addr}"))?
                    .next()
                    .ok_or_else(|| anyhow!("worker address {addr:?} resolves to nothing"))?;
                TcpStream::connect_timeout(&sa, t)
                    .with_context(|| format!("connecting worker {addr}"))?
            }
            None => {
                TcpStream::connect(addr).with_context(|| format!("connecting worker {addr}"))?
            }
        };
        // Request/response per line: Nagle only adds latency here.
        let _ = stream.set_nodelay(true);
        if let Some(t) = timeout {
            stream.set_read_timeout(Some(t))?;
            stream.set_write_timeout(Some(t))?;
        }
        Ok(WorkerClient {
            addr: addr.to_string(),
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            bin1: false,
        })
    }

    /// Negotiate `bin1` binary score frames for this connection
    /// (`{"op":"hello","frames":"bin1"}`). A worker that answers with
    /// anything but `"frames":"bin1"` — including an error from an
    /// implementation without frame support — leaves the connection in
    /// JSON mode; only a transport failure is an `Err`.
    pub fn negotiate_frames(&mut self) -> Result<bool> {
        let hello = Json::obj(vec![("op", Json::str("hello")), ("frames", Json::str("bin1"))]);
        let resp = self.request(&hello)?;
        self.bin1 = resp.opt("error").is_none()
            && resp.opt("frames").and_then(|v| v.as_str().ok()) == Some("bin1");
        Ok(self.bin1)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Adjust the read/write timeouts after connect (reader and writer
    /// are dups of one socket, so setting via the writer covers both).
    /// The tune op keeps its bounded connect but must wait unboundedly
    /// for the search to finish.
    pub fn set_io_timeout(&self, t: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(t)?;
        self.writer.set_write_timeout(t)?;
        Ok(())
    }

    /// One buffered request: write the line, read exactly one response
    /// line. Worker-side *semantic* errors come back as
    /// `Ok({"error":...})`; an `Err` means the worker itself failed
    /// (connection, timeout, garbage) and should be marked down.
    pub fn request(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", req.dump())
            .with_context(|| format!("writing to worker {}", self.addr))?;
        self.writer.flush()?;
        if self.bin1 && self.peek_byte()? == frames::MAGIC {
            bail!("worker {} sent a binary frame for a buffered request", self.addr);
        }
        self.read_response()
    }

    /// One streamed request: non-terminal units (chunks) go through
    /// `sink` — as [`Emit::Raw`] binary frames on a `bin1` connection
    /// (forwarded without decoding), else as [`Emit::Line`] JSON — and
    /// the terminal line (`"done"` present, or a bare error response for
    /// a request the worker rejected outright) is returned. Terminal
    /// lines are JSON in both modes, so one peeked byte routes each unit.
    pub fn request_streaming(&mut self, req: &Json, sink: &mut EmitSink<'_>) -> Result<Json> {
        writeln!(self.writer, "{}", req.dump())
            .with_context(|| format!("writing to worker {}", self.addr))?;
        self.writer.flush()?;
        let mut frame: Vec<u8> = Vec::new();
        loop {
            if self.bin1 && self.peek_byte()? == frames::MAGIC {
                frames::read_frame(&mut self.reader, &mut frame)
                    .with_context(|| format!("reading frame from worker {}", self.addr))?;
                sink(Emit::Raw(&frame))?;
                continue;
            }
            let line = self.read_response()?;
            let terminal = line.opt("done").is_some()
                || (line.opt("error").is_some() && line.opt("chunk").is_none());
            if terminal {
                return Ok(line);
            }
            sink(Emit::Line(&line))?;
        }
    }

    /// Peek the next response byte without consuming it: a binary frame
    /// starts with [`frames::MAGIC`], a JSON line with `{`.
    fn peek_byte(&mut self) -> Result<u8> {
        let buf = self
            .reader
            .fill_buf()
            .with_context(|| format!("reading from worker {}", self.addr))?;
        match buf.first() {
            Some(&b) => Ok(b),
            None => bail!("worker {} hung up", self.addr),
        }
    }

    fn read_response(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .with_context(|| format!("reading from worker {}", self.addr))?;
        if n == 0 {
            bail!("worker {} hung up", self.addr);
        }
        Json::parse(line.trim())
            .with_context(|| format!("bad response line from worker {}", self.addr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_spec_parses_addr_and_budget() {
        let w = WorkerSpec::parse("127.0.0.1:7878").unwrap();
        assert_eq!(w.addr, "127.0.0.1:7878");
        assert_eq!(w.budget, None);
        let w = WorkerSpec::parse("127.0.0.1:7878:500000").unwrap();
        assert_eq!(w.addr, "127.0.0.1:7878");
        assert_eq!(w.budget, Some(500_000));
        assert!(WorkerSpec::parse("justhost").is_err());
        assert!(WorkerSpec::parse("h:p:notanumber").is_err());
        assert!(WorkerSpec::parse(":7878").is_err());
        assert!(WorkerSpec::parse(":7878:100").is_err(), "empty host with budget");
        assert!(WorkerSpec::parse("host::100").is_err(), "empty port with budget");
        assert!(WorkerSpec::parse("a:b:1:2").is_err());
    }

    #[test]
    fn roster_starts_down_and_tracks_residency_notes() {
        let t = Topology::new(
            vec![
                WorkerSpec::parse("127.0.0.1:1:100").unwrap(),
                WorkerSpec::parse("127.0.0.1:2").unwrap(),
            ],
            None,
        );
        assert_eq!(t.len(), 2);
        assert!(t.up_ids().is_empty(), "workers start down until the first probe");
        t.note_loaded(0, "gpt2like_t0@fp:4:b64");
        assert_eq!(t.up_ids(), vec![0], "a successful routed load implies the worker is up");
        assert!(t.is_resident(0, "gpt2like_t0@fp:4:b64"));
        assert!(!t.is_resident(1, "gpt2like_t0@fp:4:b64"));
        assert!(!t.is_resident(7, "gpt2like_t0@fp:4:b64"), "unknown worker id is not resident");
        let snap = t.snapshot();
        assert!(snap[0].resident.contains("gpt2like_t0@fp:4:b64"));
        assert_eq!(snap[0].budget_bytes, Some(100), "roster budget used until a probe overrides");
        assert_eq!(snap[1].budget_bytes, None);
        assert!(snap[1].headroom() > snap[0].headroom(), "unbounded beats bounded headroom");
        t.note_unloaded(0, "gpt2like_t0@fp:4:b64");
        assert!(t.snapshot()[0].resident.is_empty());
        t.mark_down(0, "boom");
        assert!(t.up_ids().is_empty());
        assert_eq!(t.snapshot()[0].last_error.as_deref(), Some("boom"));
    }

    #[test]
    fn probe_marks_unreachable_workers_down() {
        // Port 1 on localhost: nothing listens; the probe must fail fast
        // and mark the worker down, not hang.
        let t = Topology::new(vec![WorkerSpec::parse("127.0.0.1:1").unwrap()], None);
        t.note_loaded(0, "k");
        t.probe_all(None);
        assert!(t.up_ids().is_empty());
        assert!(t.snapshot()[0].last_error.is_some());
    }

    #[test]
    fn parse_stats_extracts_roster_fields() {
        let j = Json::parse(
            r#"{"models":[{"key":"a@fp:4:b64","resident_bytes":10},{"key":"b@int:3:b32","resident_bytes":5}],
                "resident_bytes_total":15,"budget_bytes":100,
                "policy":{"entries":3,"suite":"ppl","hash":"00ff","source":"runs/policy.json"}}"#,
        )
        .unwrap();
        let r = parse_stats(&j).unwrap();
        assert!(r.resident.contains("a@fp:4:b64") && r.resident.contains("b@int:3:b32"));
        assert_eq!(r.resident_bytes, 15);
        assert_eq!(r.probed_budget, Some(100));
        assert_eq!(r.policy_hash.as_deref(), Some("00ff"));
        assert_eq!(r.policy_entries, 3);
        assert_eq!(r.policy_source.as_deref(), Some("runs/policy.json"));
        // Unbudgeted, policy-less worker (and pre-fleet stats without a
        // "policy" field at all).
        let j = Json::parse(
            r#"{"models":[],"resident_bytes_total":0,"budget_bytes":null,"policy":null}"#,
        )
        .unwrap();
        let r = parse_stats(&j).unwrap();
        assert_eq!(r.probed_budget, None);
        assert!(r.policy_hash.is_none());
        let j = Json::parse(r#"{"models":[],"resident_bytes_total":0,"budget_bytes":null}"#)
            .unwrap();
        assert!(parse_stats(&j).unwrap().policy_hash.is_none());
    }
}
