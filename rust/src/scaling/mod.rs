//! Scaling-law analysis: curves, Pareto frontiers, bit-level optimality.
//!
//! The paper fits **linear interpolations** over (log total-bits, metric)
//! points per bit precision — bivariate power laws fit poorly but the
//! per-precision curves are near-parallel (Section 4, "Scaling laws").
//! This module provides exactly those tools plus the analyses quoted in
//! the text: the Pareto frontier over total bits, the per-bit-budget
//! optimal precision, curve-parallelism diagnostics, and the
//! perplexity↔zero-shot Pearson correlation (paper: −0.94).

use std::collections::BTreeMap;

use crate::util::order::nan_last_cmp;

/// One evaluated point of a scaling curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    /// Total model bits (the x-axis; plotted in log10).
    pub bits: f64,
    /// The metric (mean zero-shot accuracy, or CE loss for Figs 13-15).
    pub metric: f64,
}

/// A scaling curve for one configuration group (e.g. "4-bit float"),
/// sorted by bits: piecewise-linear in (log10 bits, metric).
#[derive(Debug, Clone)]
pub struct Curve {
    pub label: String,
    points: Vec<Point>,
}

impl Curve {
    /// Non-finite points (NaN/±inf metric, non-positive or non-finite
    /// bits) are **skipped**: a single failed eval cell produces a NaN
    /// metric, and that must degrade the curve, not panic the sort that
    /// used to run `partial_cmp().unwrap()` over it. The sort itself goes
    /// through the NaN-last total order, so the constructor is total even
    /// if the filter invariant ever changes.
    pub fn new(label: impl Into<String>, mut points: Vec<Point>) -> Self {
        points.retain(|p| p.bits.is_finite() && p.bits > 0.0 && p.metric.is_finite());
        points.sort_by(|a, b| nan_last_cmp(a.bits, b.bits));
        Curve { label: label.into(), points }
    }

    pub fn points(&self) -> &[Point] {
        &self.points
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Linear interpolation in log10-bits space; clamped at the ends.
    pub fn interpolate(&self, bits: f64) -> Option<f64> {
        if self.points.is_empty() {
            return None;
        }
        let x = bits.log10();
        let xs: Vec<f64> = self.points.iter().map(|p| p.bits.log10()).collect();
        if x <= xs[0] {
            return Some(self.points[0].metric);
        }
        if x >= *xs.last().unwrap() {
            return Some(self.points.last().unwrap().metric);
        }
        let i = xs.partition_point(|&v| v < x);
        let (x0, x1) = (xs[i - 1], xs[i]);
        let (y0, y1) = (self.points[i - 1].metric, self.points[i].metric);
        Some(y0 + (y1 - y0) * (x - x0) / (x1 - x0))
    }

    /// Mean slope in (log10 bits → metric) space; curves of different
    /// precisions being near-parallel is the paper's justification for the
    /// linear-interpolation representation.
    pub fn mean_slope(&self) -> Option<f64> {
        if self.points.len() < 2 {
            return None;
        }
        let first = self.points.first().unwrap();
        let last = self.points.last().unwrap();
        Some((last.metric - first.metric) / (last.bits.log10() - first.bits.log10()))
    }
}

/// Pareto frontier for metric **maximization** (zero-shot accuracy):
/// the subset of points not dominated by any point with fewer-or-equal
/// bits and strictly higher metric. Input: `(bits, metric, tag)` triples.
/// NaN coordinates (either axis) are dropped up front — a NaN-bits point
/// has no place on the axis and a NaN metric can never "improve" on the
/// running best; the NaN-last sort keeps the pass panic-free regardless.
pub fn pareto_frontier<T: Clone>(points: &[(f64, f64, T)]) -> Vec<(f64, f64, T)> {
    let mut sorted: Vec<&(f64, f64, T)> =
        points.iter().filter(|p| !p.0.is_nan() && !p.1.is_nan()).collect();
    sorted.sort_by(|a, b| nan_last_cmp(a.0, b.0));
    let mut out: Vec<(f64, f64, T)> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        if p.1 > best {
            best = p.1;
            out.push(p.clone());
        }
    }
    out
}

/// For each curve, the metric it achieves at a given bit budget; returns
/// the best curve label per budget — the "which precision wins at fixed
/// total bits" question of Figure 1.
pub fn best_curve_at(curves: &[Curve], bits_budget: f64) -> Option<(String, f64)> {
    curves
        .iter()
        .filter_map(|c| c.interpolate(bits_budget).map(|m| (c.label.clone(), m)))
        .filter(|(_, m)| !m.is_nan())
        .max_by(|a, b| nan_last_cmp(a.1, b.1))
}

/// Count how often each curve wins across a log-spaced sweep of budgets
/// spanning the shared range — the quantitative form of "4-bit is almost
/// universally optimal".
pub fn win_counts(curves: &[Curve], n_budgets: usize) -> BTreeMap<String, usize> {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for c in curves {
        for p in c.points() {
            lo = lo.min(p.bits);
            hi = hi.max(p.bits);
        }
    }
    let mut wins: BTreeMap<String, usize> = BTreeMap::new();
    if !lo.is_finite() || !hi.is_finite() || n_budgets == 0 {
        return wins;
    }
    // Interior budgets only: at the extremes every curve is clamped and
    // comparisons are degenerate.
    for i in 0..n_budgets {
        let f = (i as f64 + 0.5) / n_budgets as f64;
        let budget = 10f64.powf(lo.log10() + f * (hi.log10() - lo.log10()));
        if let Some((label, _)) = best_curve_at(curves, budget) {
            *wins.entry(label).or_default() += 1;
        }
    }
    wins
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-300)
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b, r2)`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let b = sxy / sxx.max(1e-300);
    let a = my - b * mx;
    let r = pearson(xs, ys);
    (a, b, r * r)
}

/// Parallelism diagnostic: relative spread of mean slopes across curves
/// (small = near-parallel, the paper's observation).
pub fn slope_spread(curves: &[Curve]) -> Option<f64> {
    let slopes: Vec<f64> = curves.iter().filter_map(Curve::mean_slope).collect();
    if slopes.len() < 2 {
        return None;
    }
    let mean = slopes.iter().sum::<f64>() / slopes.len() as f64;
    let var = slopes.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / slopes.len() as f64;
    Some(var.sqrt() / mean.abs().max(1e-300))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(label: &str, pts: &[(f64, f64)]) -> Curve {
        Curve::new(label, pts.iter().map(|&(b, m)| Point { bits: b, metric: m }).collect())
    }

    #[test]
    fn interpolation_log_space() {
        let c = mk("c", &[(100.0, 0.4), (10_000.0, 0.8)]);
        assert_eq!(c.interpolate(100.0), Some(0.4));
        assert_eq!(c.interpolate(10_000.0), Some(0.8));
        // Midpoint in log space is 1000.
        assert!((c.interpolate(1000.0).unwrap() - 0.6).abs() < 1e-12);
        // Clamped outside.
        assert_eq!(c.interpolate(1.0), Some(0.4));
        assert_eq!(c.interpolate(1e9), Some(0.8));
    }

    #[test]
    fn pareto_keeps_only_improvements() {
        let pts = vec![
            (100.0, 0.5, "a"),
            (200.0, 0.4, "dominated"),
            (300.0, 0.7, "b"),
            (400.0, 0.7, "tie-dominated"),
            (500.0, 0.9, "c"),
        ];
        let front = pareto_frontier(&pts);
        let tags: Vec<&str> = front.iter().map(|p| p.2).collect();
        assert_eq!(tags, vec!["a", "b", "c"]);
    }

    #[test]
    fn four_bit_wins_in_synthetic_geometry() {
        // Construct the paper's geometry: same accuracy-vs-params family,
        // shifted left by bits/param; 4-bit strictly better than 8/16,
        // 3-bit degraded by quantization error.
        let params = [1e6, 3e6, 1e7, 3e7];
        let acc = |p: f64| 0.4 + 0.1 * (p.log10() - 6.0);
        let curve = |label: &str, bits: f64, penalty: f64| {
            mk(
                label,
                &params
                    .iter()
                    .map(|&p| (p * bits, acc(p) - penalty))
                    .collect::<Vec<_>>(),
            )
        };
        let curves = vec![
            curve("16", 16.0, 0.0),
            curve("8", 8.0, 0.002),
            curve("4", 4.0, 0.01),
            curve("3", 3.0, 0.08),
        ];
        let wins = win_counts(&curves, 40);
        let four = wins.get("4").copied().unwrap_or(0);
        let total: usize = wins.values().sum();
        assert!(four * 2 > total, "4-bit wins {four}/{total}: {wins:?}");
    }

    #[test]
    fn curve_skips_nonfinite_points_instead_of_panicking() {
        // A failed eval cell used to kill the whole tuning run via
        // partial_cmp().unwrap() in the constructor's sort.
        let c = Curve::new(
            "c",
            vec![
                Point { bits: f64::NAN, metric: 0.5 },
                Point { bits: 100.0, metric: f64::NAN },
                Point { bits: 100.0, metric: 0.4 },
                Point { bits: -5.0, metric: 0.3 },
                Point { bits: f64::INFINITY, metric: 0.9 },
                Point { bits: 10_000.0, metric: 0.8 },
            ],
        );
        assert_eq!(c.points().len(), 2, "{:?}", c.points());
        assert_eq!(c.interpolate(100.0), Some(0.4));
        assert_eq!(c.interpolate(10_000.0), Some(0.8));
        // All-bad input: an empty curve, not a panic.
        assert!(Curve::new("x", vec![Point { bits: f64::NAN, metric: f64::NAN }]).is_empty());
    }

    #[test]
    fn pareto_and_best_curve_ignore_nan_points() {
        let pts = vec![
            (f64::NAN, 9.9, "nan-bits"),
            (100.0, 0.5, "a"),
            (200.0, f64::NAN, "nan-metric"),
            (300.0, 0.7, "b"),
        ];
        let front = pareto_frontier(&pts);
        let tags: Vec<&str> = front.iter().map(|p| p.2).collect();
        assert_eq!(tags, vec!["a", "b"]);
        // best_curve_at over a curve that interpolates to NaN must not
        // panic and must prefer the finite curve.
        let good = mk("good", &[(100.0, 0.4), (10_000.0, 0.8)]);
        let empty = Curve::new("empty", vec![]);
        let best = best_curve_at(&[good, empty], 1000.0).unwrap();
        assert_eq!(best.0, "good");
    }

    #[test]
    fn pearson_known_values() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert!((pearson(&x, &x) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &neg) + 1.0).abs() < 1e-12);
        let flat = vec![1.0, 1.0, 1.0, 1.0];
        assert!(pearson(&x, &flat).abs() < 1e-6);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9 && (b - 3.0).abs() < 1e-9 && (r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slope_spread_detects_parallelism() {
        let a = mk("a", &[(1e6, 0.4), (1e7, 0.6)]);
        let b = mk("b", &[(2e6, 0.35), (2e7, 0.55)]); // parallel
        let c = mk("c", &[(1e6, 0.6), (1e7, 0.3)]); // anti-parallel
        assert!(slope_spread(&[a.clone(), b.clone()]).unwrap() < 0.05);
        assert!(slope_spread(&[a, c]).unwrap() > 1.0);
    }
}
