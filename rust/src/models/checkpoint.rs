//! Checkpoint store: trained parameters on disk, keyed by [`ModelId`].
//!
//! Uses the `tensor::save_tensors` binary container. Checkpoints carry
//! their training metadata in a JSON sidecar so sweep results can record
//! provenance (steps, final loss, corpus seed).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::tensor::{load_tensors, save_tensors, Tensor};
use crate::util::json::Json;

use super::ModelId;

/// Training provenance stored next to each checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub steps: usize,
    pub final_loss: f64,
    pub corpus_seed: u64,
}

/// Directory-backed checkpoint store.
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointStore { dir: dir.into() }
    }

    pub fn path(&self, id: &ModelId) -> PathBuf {
        self.dir.join(format!("{}.bin", id.key()))
    }

    fn meta_path(&self, id: &ModelId) -> PathBuf {
        self.dir.join(format!("{}.meta.json", id.key()))
    }

    pub fn exists(&self, id: &ModelId) -> bool {
        self.path(id).exists() && self.meta_path(id).exists()
    }

    pub fn save(
        &self,
        id: &ModelId,
        params: &[(String, Tensor)],
        meta: &CheckpointMeta,
    ) -> Result<()> {
        let named: Vec<(&str, &Tensor)> =
            params.iter().map(|(n, t)| (n.as_str(), t)).collect();
        save_tensors(&self.path(id), &named)?;
        let j = Json::obj(vec![
            ("steps", Json::num(meta.steps as f64)),
            ("final_loss", Json::num(meta.final_loss)),
            ("corpus_seed", Json::num(meta.corpus_seed as f64)),
        ]);
        std::fs::write(self.meta_path(id), j.dump())?;
        Ok(())
    }

    pub fn load(&self, id: &ModelId) -> Result<(Vec<(String, Tensor)>, CheckpointMeta)> {
        let params = load_tensors(&self.path(id)).with_context(|| {
            format!("loading checkpoint for {id} (run `kbitscale train` first)")
        })?;
        let meta_text = std::fs::read_to_string(self.meta_path(id))?;
        let j = Json::parse(&meta_text)?;
        let meta = CheckpointMeta {
            steps: j.get("steps")?.as_usize()?,
            final_loss: j.get("final_loss")?.as_f64()?,
            corpus_seed: j.get("corpus_seed")?.as_f64()? as u64,
        };
        Ok((params, meta))
    }

    /// All checkpoint ids present on disk (for `kbitscale status`).
    pub fn list(&self) -> Vec<String> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut out: Vec<String> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                name.strip_suffix(".bin").map(str::to_string)
            })
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (CheckpointStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!("kbt_ckpt_{}_{:?}", std::process::id(), std::thread::current().id()));
        (CheckpointStore::new(&dir), dir)
    }

    #[test]
    fn save_load_roundtrip() {
        let (s, dir) = store();
        let id = ModelId::new("gpt2like", "t0");
        let params = vec![
            ("embed".to_string(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])),
            ("lnf_s".to_string(), Tensor::ones(vec![3])),
        ];
        let meta = CheckpointMeta { steps: 100, final_loss: 3.25, corpus_seed: 7 };
        assert!(!s.exists(&id));
        s.save(&id, &params, &meta).unwrap();
        assert!(s.exists(&id));
        let (loaded, lmeta) = s.load(&id).unwrap();
        assert_eq!(loaded, params);
        assert_eq!(lmeta, meta);
        assert_eq!(s.list(), vec!["gpt2like_t0"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_missing_mentions_train() {
        let (s, dir) = store();
        let err = s.load(&ModelId::new("optlike", "t5")).unwrap_err();
        assert!(format!("{err:#}").contains("train"));
        std::fs::remove_dir_all(dir).ok();
    }
}
