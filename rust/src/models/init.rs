//! Parameter initialization, including emergent-outlier injection.
//!
//! Mirrors `model.init_params` on the python side (GPT-2 convention:
//! std-0.02 normals, residual projections scaled by `1 / sqrt(2 L)`,
//! LayerNorm scale 1 / bias 0), then applies the family's outlier recipe:
//! a deterministic set of residual dimensions has its weights multiplied
//! in the residual-writing matrices (`wo`, `fc2` output columns and the
//! embedding), seeding the outlier features that make OPT/Pythia-like
//! models fragile at 3-bit. The same dims are amplified at every layer —
//! matching the observation that real outlier features occupy the *same*
//! hidden dimensions across layers (Dettmers et al., 2022a).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::families::Family;
use super::manifest::TierManifest;

/// Initialize all parameters for `(family, tier)` in manifest order.
pub fn init_params(tier: &TierManifest, family: &Family) -> Vec<(String, Tensor)> {
    let mut rng = Rng::new(family.seed ^ crate::util::fnv1a(tier.name.as_bytes()));
    let resid_scale = 0.02 / (2.0 * tier.n_layer as f64).sqrt();

    let mut params: Vec<(String, Tensor)> = tier
        .params
        .iter()
        .map(|p| {
            let mut stream = rng.fork(crate::util::fnv1a(p.name.as_bytes()));
            let mut t = Tensor::zeros(p.shape.clone());
            if p.name.ends_with("_s") {
                t = Tensor::ones(p.shape.clone());
            } else if p.name.ends_with("_b") {
                // zeros already
            } else if p.name == "wo" || p.name == "fc2" {
                stream.fill_normal(t.data_mut(), resid_scale as f32);
            } else {
                stream.fill_normal(t.data_mut(), 0.02);
            }
            (p.name.clone(), t)
        })
        .collect();

    if let Some(recipe) = family.outliers {
        let dims = outlier_dims(tier.d_model, recipe.dim_fraction, family.seed);
        inject_outliers(&mut params, &dims, recipe.scale, tier);
    }
    params
}

/// The deterministic outlier dimension set for a family at width `d`.
pub fn outlier_dims(d_model: usize, fraction: f64, seed: u64) -> Vec<usize> {
    let n = ((d_model as f64 * fraction).ceil() as usize).clamp(1, d_model);
    let mut rng = Rng::new(seed ^ 0x0DD5);
    rng.sample_indices(d_model, n)
}

/// Amplify `dims` of the residual stream in every residual writer.
///
/// * `embed` — columns `dims` scaled (the stream starts hot there),
/// * `wo`, `fc2` — output columns `dims` scaled in every layer.
pub fn inject_outliers(
    params: &mut [(String, Tensor)],
    dims: &[usize],
    scale: f32,
    tier: &TierManifest,
) {
    let d = tier.d_model;
    for (name, t) in params.iter_mut() {
        match name.as_str() {
            // NOTE: embed columns are deliberately NOT scaled — amplifying
            // the input stream destabilizes training; weight-side outliers
            // in the residual writers reproduce the quantization fragility
            // without hurting trainability.
            "wo" | "fc2" => {
                let shape = t.shape().to_vec();
                let (l, rows, cols) = (shape[0], shape[1], shape[2]);
                assert_eq!(cols, d);
                let data = t.data_mut();
                for li in 0..l {
                    for r in 0..rows {
                        let base = li * rows * cols + r * cols;
                        for &c in dims {
                            data[base + c] *= scale;
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::ParamInfo;

    fn tiny_tier() -> TierManifest {
        let d = 16;
        let l = 2;
        let f = 4 * d;
        TierManifest {
            name: "tt".into(),
            d_model: d,
            n_layer: l,
            n_head: 2,
            d_ff: f,
            vocab: 64,
            seq: 16,
            batch_train: 2,
            batch_eval: 2,
            param_count: 0,
            params: vec![
                ParamInfo { name: "embed".into(), shape: vec![64, d] },
                ParamInfo { name: "pos".into(), shape: vec![16, d] },
                ParamInfo { name: "qkv".into(), shape: vec![l, d, 3 * d] },
                ParamInfo { name: "wo".into(), shape: vec![l, d, d] },
                ParamInfo { name: "fc1".into(), shape: vec![l, d, f] },
                ParamInfo { name: "fc2".into(), shape: vec![l, f, d] },
                ParamInfo { name: "ln1_s".into(), shape: vec![l, d] },
                ParamInfo { name: "ln1_b".into(), shape: vec![l, d] },
                ParamInfo { name: "ln2_s".into(), shape: vec![l, d] },
                ParamInfo { name: "ln2_b".into(), shape: vec![l, d] },
                ParamInfo { name: "lnf_s".into(), shape: vec![d] },
                ParamInfo { name: "lnf_b".into(), shape: vec![d] },
            ],
            quantized_params: ["qkv", "wo", "fc1", "fc2"].iter().map(|s| s.to_string()).collect(),
            fwd_hlo: "x".into(),
            train_hlo: "y".into(),
            acts_hlo: None,
            stages: Vec::new(),
        }
    }

    #[test]
    fn init_is_deterministic_per_family() {
        let tier = tiny_tier();
        let f = Family::get("gpt2like").unwrap();
        let a = init_params(&tier, f);
        let b = init_params(&tier, f);
        for ((n1, t1), (_, t2)) in a.iter().zip(&b) {
            assert_eq!(t1, t2, "{n1}");
        }
        // Different family -> different init.
        let c = init_params(&tier, Family::get("bloomlike").unwrap());
        assert!(a[0].1.max_abs_diff(&c[0].1) > 0.0);
    }

    #[test]
    fn layernorm_init_is_identity() {
        let params = init_params(&tiny_tier(), Family::get("gpt2like").unwrap());
        let by: std::collections::BTreeMap<_, _> =
            params.iter().map(|(n, t)| (n.as_str(), t)).collect();
        assert!(by["ln1_s"].data().iter().all(|&x| x == 1.0));
        assert!(by["ln1_b"].data().iter().all(|&x| x == 0.0));
        assert!(by["lnf_s"].data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn outlier_family_has_hot_columns() {
        let tier = tiny_tier();
        let opt = init_params(&tier, Family::get("optlike").unwrap());
        let by: std::collections::BTreeMap<_, _> =
            opt.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let wo = by["wo"];
        // Column stds must be strongly bimodal: max/median > half the scale.
        let stds = crate::quant::proxy::column_stds(&wo.data()[..16 * 16], 16, 16);
        let mut sorted = stds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[8];
        let max = sorted[15];
        assert!(max / median > 5.0, "max {max} median {median}");
    }

    #[test]
    fn outlier_dims_stable_and_sized() {
        let a = outlier_dims(128, 0.04, 42);
        let b = outlier_dims(128, 0.04, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6); // ceil(128 * 0.04)
        assert!(outlier_dims(16, 0.01, 1).len() == 1); // minimum 1
    }

    #[test]
    fn stable_family_has_no_hot_columns() {
        let tier = tiny_tier();
        let g = init_params(&tier, Family::get("gpt2like").unwrap());
        let by: std::collections::BTreeMap<_, _> =
            g.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let stds = crate::quant::proxy::column_stds(&by["wo"].data()[..16 * 16], 16, 16);
        let mut sorted = stds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted[15] / sorted[8] < 3.0, "unexpected outlier in stable family");
    }
}
