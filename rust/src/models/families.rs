//! The five synthetic model families.
//!
//! The paper's families (OPT, Pythia, GPT-2, BLOOM, BLOOMZ) differ, for
//! quantization purposes, in whether they develop **emergent outlier
//! features**: OPT and Pythia do (and are unstable at 3-bit), GPT-2 and
//! BLOOM are comparatively stable (Figure 2), and BLOOMZ is a fine-tune of
//! BLOOM with essentially identical quantization behaviour (Appendix C.1).
//!
//! We reproduce that split mechanically: each family fixes a training
//! seed, a learning-rate scale, and an outlier-injection recipe applied at
//! initialization and (through training dynamics) persisting in the
//! residual-writing weights — a few hidden dimensions whose weights are
//! `outlier_scale`x larger, scaling with width like real emergent outliers
//! (Dettmers et al., 2022a).

/// Outlier-injection recipe (see `init::inject_outliers`).
#[derive(Debug, Clone, Copy)]
pub struct OutlierRecipe {
    /// Number of outlier dims as a fraction of `d_model` (rounded up,
    /// minimum 1 when fraction > 0).
    pub dim_fraction: f64,
    /// Multiplier on those dims' weights (paper §3 observes up to 20x).
    pub scale: f32,
}

/// A family: training recipe + outlier behaviour.
#[derive(Debug, Clone, Copy)]
pub struct Family {
    pub name: &'static str,
    pub seed: u64,
    /// Multiplier on the base learning rate.
    pub lr_scale: f64,
    /// `None` = no emergent outliers (GPT-2/BLOOM-like).
    pub outliers: Option<OutlierRecipe>,
    /// Fine-tuned from this family's checkpoint instead of trained from
    /// scratch (BLOOMZ-like).
    pub finetune_of: Option<&'static str>,
}

/// The family zoo. Names are suffixed "-like": these are synthetic models
/// with the *quantization-relevant* traits of their namesakes, not
/// replicas (DESIGN.md §1).
pub const FAMILIES: [Family; 5] = [
    Family {
        name: "optlike",
        seed: 101,
        lr_scale: 1.0,
        outliers: Some(OutlierRecipe { dim_fraction: 0.05, scale: 25.0 }),
        finetune_of: None,
    },
    Family {
        name: "pythialike",
        seed: 202,
        lr_scale: 1.0,
        outliers: Some(OutlierRecipe { dim_fraction: 0.04, scale: 15.0 }),
        finetune_of: None,
    },
    Family {
        name: "gpt2like",
        seed: 303,
        lr_scale: 1.0,
        outliers: None,
        finetune_of: None,
    },
    Family {
        name: "bloomlike",
        seed: 404,
        lr_scale: 0.8,
        outliers: None,
        finetune_of: None,
    },
    Family {
        name: "bloomzlike",
        seed: 505,
        lr_scale: 0.3,
        outliers: None,
        finetune_of: Some("bloomlike"),
    },
];

impl Family {
    pub fn get(name: &str) -> anyhow::Result<&'static Family> {
        FAMILIES
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown family {name:?} (have: {:?})",
                FAMILIES.iter().map(|f| f.name).collect::<Vec<_>>()
            ))
    }

    /// The four from-scratch families of the headline figures.
    pub fn headline() -> Vec<&'static Family> {
        FAMILIES.iter().filter(|f| f.finetune_of.is_none()).collect()
    }

    pub fn has_outliers(&self) -> bool {
        self.outliers.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_shape() {
        assert_eq!(FAMILIES.len(), 5);
        assert_eq!(Family::headline().len(), 4);
        assert!(Family::get("optlike").unwrap().has_outliers());
        assert!(!Family::get("gpt2like").unwrap().has_outliers());
        assert!(Family::get("nope").is_err());
    }

    #[test]
    fn seeds_are_distinct() {
        let mut seeds: Vec<u64> = FAMILIES.iter().map(|f| f.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), FAMILIES.len());
    }

    #[test]
    fn finetune_parent_exists() {
        for f in FAMILIES.iter() {
            if let Some(parent) = f.finetune_of {
                assert!(Family::get(parent).is_ok(), "{} -> {parent}", f.name);
            }
        }
    }
}
