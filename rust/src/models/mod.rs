//! Model zoo: AOT manifest, synthetic families, initialization, and
//! checkpoint storage.
//!
//! The study spans **families × tiers**: a tier fixes the architecture
//! shapes (read from `artifacts/manifest.json`, the single source of truth
//! shared with the AOT compiler), a family fixes the training recipe —
//! seed, learning-rate scale, and most importantly the **emergent-outlier
//! injection** that makes OPT-like and Pythia-like models unstable at
//! 3-bit, reproducing the paper's Figure 2/4 family split (DESIGN.md §1).

pub mod checkpoint;
pub mod families;
pub mod init;
pub mod manifest;

pub use checkpoint::CheckpointStore;
pub use families::{Family, FAMILIES};
pub use manifest::{Manifest, TierManifest};

/// A fully-identified model in the zoo.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelId {
    pub family: &'static str,
    pub tier: String,
}

impl ModelId {
    pub fn new(family: &'static str, tier: impl Into<String>) -> Self {
        ModelId { family, tier: tier.into() }
    }

    /// Stable key used for checkpoints and the results store.
    pub fn key(&self) -> String {
        format!("{}_{}", self.family, self.tier)
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.family, self.tier)
    }
}
