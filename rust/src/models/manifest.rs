//! Parser for `artifacts/manifest.json` — the contract between the AOT
//! compiler (`python/compile/aot.py`) and the Rust runtime. Shapes,
//! argument order, and kernel geometry all come from here; nothing about
//! tensor layout is hard-coded on the Rust side.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One parameter tensor's name and shape, in executable argument order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One stage parameter reference: a tier parameter, optionally sliced
/// along its leading (layer) axis. `layers == None` means the whole
/// tensor; `Some((lo, hi))` selects stacked layers `[lo, hi)` — a
/// contiguous slice of the checkpoint tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct StageParamRef {
    pub source: String,
    pub layers: Option<(usize, usize)>,
}

/// One pipeline stage of a sharded execution plan: an HLO artifact with
/// the tier parameters it owns and its output arity. Stages chain by
/// activation handoff under the uniform calling convention
/// `(stage params…, carried…, tokens, mask) -> carried'`; the final
/// stage returns `(nll, hits)`.
#[derive(Debug, Clone)]
pub struct StageManifest {
    pub name: String,
    pub hlo: String,
    pub params: Vec<StageParamRef>,
    /// Output leaves this stage's graph returns (carried into the next
    /// stage; the last stage must return 2).
    pub outputs: usize,
}

/// Static description of one model scale.
#[derive(Debug, Clone)]
pub struct TierManifest {
    pub name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub param_count: usize,
    pub params: Vec<ParamInfo>,
    pub quantized_params: Vec<String>,
    pub fwd_hlo: String,
    pub train_hlo: String,
    /// GPTQ calibration-activation graph (absent in pre-v2 manifests).
    pub acts_hlo: Option<String>,
    /// Pipeline-sharded execution plan stages (empty in pre-v3 manifests:
    /// only the monolithic single-stage plan is available then).
    pub stages: Vec<StageManifest>,
}

impl TierManifest {
    /// `(name, numel)` pairs for total-bits accounting.
    pub fn param_sizes(&self) -> Vec<(String, usize)> {
        self.params.iter().map(|p| (p.name.clone(), p.numel())).collect()
    }
}

/// Geometry of the standalone fused-kernel artifacts.
#[derive(Debug, Clone)]
pub struct KernelManifest {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub qblock: usize,
    pub codebook_pad: usize,
    pub u8_hlo: String,
    pub packed4_hlo: String,
    pub f32_hlo: String,
}

/// The full artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub seq: usize,
    pub param_names: Vec<String>,
    pub tiers: Vec<TierManifest>,
    pub kernels: KernelManifest,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let tiers = j
            .get("tiers")?
            .as_arr()?
            .iter()
            .map(parse_tier)
            .collect::<Result<Vec<_>>>()?;
        if tiers.is_empty() {
            bail!("manifest has no tiers");
        }

        let k = j.get("kernels")?;
        let kernels = KernelManifest {
            m: k.get("m")?.as_usize()?,
            k: k.get("k")?.as_usize()?,
            n: k.get("n")?.as_usize()?,
            qblock: k.get("qblock")?.as_usize()?,
            codebook_pad: k.get("codebook_pad")?.as_usize()?,
            u8_hlo: k.get("u8_hlo")?.as_str()?.to_string(),
            packed4_hlo: k.get("packed4_hlo")?.as_str()?.to_string(),
            f32_hlo: k.get("f32_hlo")?.as_str()?.to_string(),
        };

        Ok(Manifest {
            dir: artifacts_dir.to_path_buf(),
            vocab: j.get("vocab")?.as_usize()?,
            seq: j.get("seq")?.as_usize()?,
            param_names: j
                .get("param_names")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            tiers,
            kernels,
        })
    }

    pub fn tier(&self, name: &str) -> Result<&TierManifest> {
        self.tiers
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("tier {name:?} not in manifest (have: {:?})",
                self.tiers.iter().map(|t| &t.name).collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_tier(j: &Json) -> Result<TierManifest> {
    Ok(TierManifest {
        name: j.get("name")?.as_str()?.to_string(),
        d_model: j.get("d_model")?.as_usize()?,
        n_layer: j.get("n_layer")?.as_usize()?,
        n_head: j.get("n_head")?.as_usize()?,
        d_ff: j.get("d_ff")?.as_usize()?,
        vocab: j.get("vocab")?.as_usize()?,
        seq: j.get("seq")?.as_usize()?,
        batch_train: j.get("batch_train")?.as_usize()?,
        batch_eval: j.get("batch_eval")?.as_usize()?,
        param_count: j.get("param_count")?.as_usize()?,
        params: j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usizes()?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        quantized_params: j
            .get("quantized_params")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?,
        fwd_hlo: j.get("fwd_hlo")?.as_str()?.to_string(),
        train_hlo: j.get("train_hlo")?.as_str()?.to_string(),
        acts_hlo: j.opt("acts_hlo").and_then(|v| v.as_str().ok().map(str::to_string)),
        stages: match j.opt("stages") {
            Some(s) => s.as_arr()?.iter().map(parse_stage).collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        },
    })
}

fn parse_stage(j: &Json) -> Result<StageManifest> {
    Ok(StageManifest {
        name: j.get("name")?.as_str()?.to_string(),
        hlo: j.get("hlo")?.as_str()?.to_string(),
        outputs: j.get("outputs")?.as_usize()?,
        params: j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                let layers = match (p.opt("lo"), p.opt("hi")) {
                    (Some(lo), Some(hi)) => Some((lo.as_usize()?, hi.as_usize()?)),
                    (None, None) => None,
                    _ => bail!("stage param needs both lo and hi (or neither)"),
                };
                Ok(StageParamRef { source: p.get("source")?.as_str()?.to_string(), layers })
            })
            .collect::<Result<Vec<_>>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal manifest JSON fixture in a temp dir.
    fn fixture() -> (tempdir::TempDirGuard, Manifest) {
        let dir = tempdir::guard("manifest_test");
        let json = r#"{
            "version": 1, "vocab": 512, "seq": 64,
            "param_names": ["embed", "qkv"],
            "tiers": [{
                "name": "t0", "d_model": 32, "n_layer": 2, "n_head": 2,
                "d_ff": 128, "vocab": 512, "seq": 64,
                "batch_train": 8, "batch_eval": 16, "param_count": 43328,
                "params": [
                    {"name": "embed", "shape": [512, 32]},
                    {"name": "qkv", "shape": [2, 32, 96]}
                ],
                "quantized_params": ["qkv"],
                "fwd_hlo": "fwd_t0.hlo.txt", "train_hlo": "train_t0.hlo.txt"
            }],
            "kernels": {
                "m": 16, "k": 512, "n": 512, "qblock": 64, "codebook_pad": 256,
                "u8_hlo": "a.hlo.txt", "packed4_hlo": "b.hlo.txt", "f32_hlo": "c.hlo.txt"
            }
        }"#;
        std::fs::write(dir.path.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir.path).unwrap();
        (dir, m)
    }

    mod tempdir {
        use std::path::PathBuf;

        pub struct TempDirGuard {
            pub path: PathBuf,
        }

        impl Drop for TempDirGuard {
            fn drop(&mut self) {
                std::fs::remove_dir_all(&self.path).ok();
            }
        }

        pub fn guard(tag: &str) -> TempDirGuard {
            let path = std::env::temp_dir().join(format!("kbt_{tag}_{}", std::process::id()));
            std::fs::create_dir_all(&path).unwrap();
            TempDirGuard { path }
        }
    }

    #[test]
    fn parses_fixture() {
        let (_g, m) = fixture();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.tiers.len(), 1);
        let t = m.tier("t0").unwrap();
        assert_eq!(t.params[1].shape, vec![2, 32, 96]);
        assert_eq!(t.params[1].numel(), 2 * 32 * 96);
        assert_eq!(t.quantized_params, vec!["qkv"]);
        assert_eq!(m.kernels.qblock, 64);
        assert!(m.tier("t9").is_err());
    }

    #[test]
    fn param_sizes_sum() {
        let (_g, m) = fixture();
        let sizes = m.tier("t0").unwrap().param_sizes();
        let total: usize = sizes.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 512 * 32 + 2 * 32 * 96);
    }

    #[test]
    fn parses_pipeline_stages() {
        let (_g, m) = fixture();
        // Pre-v3 fixture: no stages block -> empty (monolithic only).
        assert!(m.tier("t0").unwrap().stages.is_empty());

        let dir = tempdir::guard("manifest_stages");
        let json = r#"{
            "version": 1, "vocab": 512, "seq": 64,
            "param_names": ["embed", "qkv"],
            "tiers": [{
                "name": "t0", "d_model": 32, "n_layer": 2, "n_head": 2,
                "d_ff": 128, "vocab": 512, "seq": 64,
                "batch_train": 8, "batch_eval": 16, "param_count": 43328,
                "params": [
                    {"name": "embed", "shape": [512, 32]},
                    {"name": "qkv", "shape": [2, 32, 96]}
                ],
                "quantized_params": ["qkv"],
                "fwd_hlo": "fwd_t0.hlo.txt", "train_hlo": "train_t0.hlo.txt",
                "stages": [
                    {"name": "s0", "hlo": "fwd_a_t0.hlo.txt", "outputs": 1,
                     "params": [{"source": "embed"},
                                {"source": "qkv", "lo": 0, "hi": 1}]},
                    {"name": "s1", "hlo": "fwd_b_t0.hlo.txt", "outputs": 2,
                     "params": [{"source": "qkv", "lo": 1, "hi": 2},
                                {"source": "embed"}]}
                ]
            }],
            "kernels": {
                "m": 16, "k": 512, "n": 512, "qblock": 64, "codebook_pad": 256,
                "u8_hlo": "a.hlo.txt", "packed4_hlo": "b.hlo.txt", "f32_hlo": "c.hlo.txt"
            }
        }"#;
        std::fs::write(dir.path.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir.path).unwrap();
        let t = m.tier("t0").unwrap();
        assert_eq!(t.stages.len(), 2);
        assert_eq!(t.stages[0].name, "s0");
        assert_eq!(t.stages[0].outputs, 1);
        assert_eq!(t.stages[0].params[0], StageParamRef { source: "embed".into(), layers: None });
        assert_eq!(
            t.stages[1].params[0],
            StageParamRef { source: "qkv".into(), layers: Some((1, 2)) }
        );
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
