//! Figure and table rendering: ASCII plots for the terminal, CSV sidecars
//! for external plotting. Every `benches/` target and the `figures` CLI
//! subcommand emit through this module so output formats stay uniform.

pub mod figures;

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::scaling::Curve;

/// Render multiple curves as an ASCII scatter/line chart in (log10 x, y).
///
/// Each curve gets a distinct glyph; a legend follows the grid. This is
/// the terminal rendition of the paper's matplotlib panels.
pub fn ascii_chart(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    curves: &[Curve],
    width: usize,
    height: usize,
) -> String {
    const GLYPHS: [char; 10] = ['o', '+', 'x', '*', '#', '@', '%', '&', '=', '~'];
    let mut out = String::new();
    let _ = writeln!(out, "{title}");

    let pts: Vec<(f64, f64)> = curves
        .iter()
        .flat_map(|c| c.points().iter().map(|p| (p.bits.log10(), p.metric)))
        .collect();
    if pts.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let pad = (y1 - y0) * 0.05;
    y0 -= pad;
    y1 += pad;

    let mut grid = vec![vec![' '; width]; height];
    for (ci, c) in curves.iter().enumerate() {
        let glyph = GLYPHS[ci % GLYPHS.len()];
        // Plot interpolated line plus the actual points.
        for col in 0..width {
            let x = x0 + (x1 - x0) * col as f64 / (width - 1) as f64;
            if let Some(y) = c.interpolate(10f64.powf(x)) {
                let lo = c.points().first().unwrap().bits.log10();
                let hi = c.points().last().unwrap().bits.log10();
                if x < lo - 1e-9 || x > hi + 1e-9 {
                    continue;
                }
                let row = ((y1 - y) / (y1 - y0) * (height - 1) as f64).round() as usize;
                let row = row.min(height - 1);
                if grid[row][col] == ' ' {
                    grid[row][col] = '.';
                }
            }
        }
        for p in c.points() {
            let col = (((p.bits.log10() - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let row = ((y1 - p.metric) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = glyph;
        }
    }

    for (r, row) in grid.iter().enumerate() {
        let ytick = y1 - (y1 - y0) * r as f64 / (height - 1) as f64;
        let _ = writeln!(out, "{ytick:>8.3} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>8} +{}", "", "-".repeat(width));
    let _ = writeln!(
        out,
        "{:>8}  {:<w$.3}{:>w2$.3}  ({xlabel}, log10)",
        "",
        x0,
        x1,
        w = width / 2,
        w2 = width - width / 2
    );
    let _ = writeln!(out, "  y: {ylabel}");
    for (ci, c) in curves.iter().enumerate() {
        let _ = writeln!(out, "    {} {}", GLYPHS[ci % GLYPHS.len()], c.label);
    }
    out
}

/// Write curves to CSV: `label,bits,metric` rows.
pub fn write_csv(path: &Path, curves: &[Curve]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = String::from("label,bits,metric\n");
    for c in curves {
        for p in c.points() {
            let _ = writeln!(s, "{},{},{}", c.label, p.bits, p.metric);
        }
    }
    std::fs::write(path, s)?;
    Ok(())
}

/// Fixed-width table formatting (Table 1 and friends).
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(out, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scaling::Point;

    fn curve(label: &str) -> Curve {
        Curve::new(
            label,
            vec![
                Point { bits: 1e6, metric: 0.4 },
                Point { bits: 1e7, metric: 0.6 },
                Point { bits: 1e8, metric: 0.7 },
            ],
        )
    }

    #[test]
    fn chart_contains_structure() {
        let s = ascii_chart("Fig X", "total bits", "acc", &[curve("4-bit"), curve("8-bit")], 60, 12);
        assert!(s.contains("Fig X"));
        assert!(s.contains("4-bit") && s.contains("8-bit"));
        assert!(s.contains('o') && s.contains('+'));
        assert!(s.lines().count() > 12);
    }

    #[test]
    fn chart_empty_is_graceful() {
        let s = ascii_chart("empty", "x", "y", &[], 40, 8);
        assert!(s.contains("no data"));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("kbt_csv_{}", std::process::id()));
        let path = dir.join("fig.csv");
        write_csv(&path, &[curve("c1")]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4); // header + 3 points
        assert!(text.starts_with("label,bits,metric"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn table_alignment() {
        let mut t = TextTable::new(&["Blocksize", "2-bit GPTQ", "3-bit Float"]);
        t.row(vec!["1024".into(), "11.84".into(), "13.26".into()]);
        t.row(vec!["64".into(), "9.18".into(), "9.99".into()]);
        let s = t.render();
        assert!(s.contains("Blocksize"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].matches('-').count(), lines[0].len() - 4); // separators
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
