//! Figure assembly: results store → scaling curves → ASCII/CSV artifacts.
//!
//! Shared by the `figures` CLI subcommand and the `benches/` reproduction
//! targets, so every rendering of "Figure N" comes from the same code.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::CellResult;
use crate::scaling::{Curve, Point};

use super::{ascii_chart, write_csv};

/// Parse the bit width out of a spec key (`fp:4:b64` → 4, `fp:16:bnone` → 16).
pub fn spec_bits(spec_key: &str) -> Option<usize> {
    spec_key.split(':').nth(1)?.parse().ok()
}

/// Parse the data type out of a spec key.
pub fn spec_dtype(spec_key: &str) -> &str {
    spec_key.split(':').next().unwrap_or("?")
}

/// Parse the block size (`b64` → Some(64), `bnone` → None).
pub fn spec_block(spec_key: &str) -> Option<usize> {
    spec_key
        .split(':')
        .nth(2)
        .and_then(|b| b.strip_prefix('b'))
        .and_then(|b| b.parse().ok())
}

pub fn spec_has_proxy(spec_key: &str) -> bool {
    spec_key.split(':').any(|p| p.starts_with('p') && p[1..].parse::<f64>().is_ok())
}

/// Metric selector for curve building.
#[derive(Clone, Copy)]
pub enum Metric {
    ZsMean,
    Ce,
}

impl Metric {
    fn get(self, r: &CellResult) -> Option<f64> {
        match self {
            Metric::ZsMean => r.zs_mean.is_finite().then_some(r.zs_mean),
            Metric::Ce => r.ce.is_finite().then_some(r.ce),
        }
    }
    pub fn label(self) -> &'static str {
        match self {
            Metric::ZsMean => "mean zero-shot accuracy",
            Metric::Ce => "CE loss (nats/token)",
        }
    }
}

/// Group results into curves: `label_of` names the curve a result belongs
/// to (None = excluded); x = total bits, y = metric.
pub fn build_curves<F>(results: &[CellResult], metric: Metric, label_of: F) -> Vec<Curve>
where
    F: Fn(&CellResult) -> Option<String>,
{
    use std::collections::BTreeMap;
    let mut by: BTreeMap<String, Vec<Point>> = BTreeMap::new();
    for r in results {
        let Some(label) = label_of(r) else { continue };
        let Some(y) = metric.get(r) else { continue };
        by.entry(label).or_default().push(Point { bits: r.total_bits, metric: y });
    }
    by.into_iter()
        .filter(|(_, pts)| pts.len() >= 2)
        .map(|(label, pts)| Curve::new(label, pts))
        .collect()
}

/// Per-precision curves (the headline-figure grouping). Optionally filter
/// to one family.
pub fn bit_curves(results: &[CellResult], family: Option<&str>) -> Vec<Curve> {
    build_curves(results, Metric::ZsMean, |r| {
        if let Some(f) = family {
            if r.family != f {
                return None;
            }
        }
        if spec_has_proxy(&r.spec_key) {
            return None;
        }
        spec_bits(&r.spec_key).map(|b| format!("{b}-bit"))
    })
}

/// Render a named figure set from the store. `which` = "all" or a number.
/// Returns rendered text blocks (also written as CSV under `out_dir`).
pub fn render_known(
    store: &crate::coordinator::ResultsStore,
    out_dir: &Path,
    which: &str,
) -> Result<Vec<String>> {
    let all = store.all();
    let mut out = Vec::new();
    let want = |n: &str| which == "all" || which == n;

    if want("1") {
        let curves = bit_curves(&all, Some("optlike"));
        out.push(render_one(out_dir, "fig1_optlike_bit_scaling",
            "Figure 1: bit-level scaling, OPT-like family (mean zero-shot vs total bits)",
            Metric::ZsMean, curves)?);
    }
    if want("2") || want("7") {
        for family in ["optlike", "pythialike", "gpt2like", "bloomlike"] {
            let curves = bit_curves(&all, Some(family));
            if curves.is_empty() {
                continue;
            }
            out.push(render_one(out_dir, &format!("fig2_{family}"),
                &format!("Figure 2/7 panel: bit-level scaling, {family}"),
                Metric::ZsMean, curves)?);
        }
    }
    if want("3") {
        let dt = build_curves(&all, Metric::ZsMean, |r| {
            (r.family == "pythialike" && spec_bits(&r.spec_key) == Some(4)
                && spec_block(&r.spec_key) == Some(64) && !spec_has_proxy(&r.spec_key))
                .then(|| format!("4-bit {}", spec_dtype(&r.spec_key)))
        });
        out.push(render_one(out_dir, "fig3_datatypes",
            "Figure 3 (left): 4-bit Pythia-like data types", Metric::ZsMean, dt)?);
        let bs = build_curves(&all, Metric::ZsMean, |r| {
            (r.family == "pythialike" && spec_bits(&r.spec_key) == Some(4)
                && spec_dtype(&r.spec_key) == "fp" && !spec_has_proxy(&r.spec_key))
                .then(|| match spec_block(&r.spec_key) {
                    Some(b) => format!("block {b}"),
                    None => "no blocking".to_string(),
                })
        });
        out.push(render_one(out_dir, "fig3_blocksizes",
            "Figure 3 (right): 4-bit Pythia-like block sizes", Metric::ZsMean, bs)?);
    }
    if want("4") {
        for family in ["optlike", "pythialike"] {
            let curves = build_curves(&all, Metric::ZsMean, |r| {
                if r.family != family {
                    return None;
                }
                let bits = spec_bits(&r.spec_key)?;
                if bits != 3 && bits != 4 && bits != 16 {
                    return None;
                }
                let proxy = if spec_has_proxy(&r.spec_key) { "+proxy" } else { "" };
                Some(format!("{bits}-bit{proxy}"))
            });
            if !curves.is_empty() {
                out.push(render_one(out_dir, &format!("fig4_proxy_{family}"),
                    &format!("Figure 4: proxy quantization, {family}"), Metric::ZsMean, curves)?);
            }
        }
    }
    if want("13") {
        let curves = build_curves(&all, Metric::Ce, |r| {
            if spec_has_proxy(&r.spec_key) {
                return None;
            }
            spec_bits(&r.spec_key).map(|b| format!("{b}-bit"))
        });
        out.push(render_one(out_dir, "fig13_ce_scaling",
            "Figure 13: CE-loss scaling across all families", Metric::Ce, curves)?);
    }
    if out.is_empty() {
        anyhow::bail!("no figure data for {which:?} — run the matching sweep first");
    }
    Ok(out)
}

fn render_one(
    out_dir: &Path,
    stem: &str,
    title: &str,
    metric: Metric,
    curves: Vec<Curve>,
) -> Result<String> {
    write_csv(&out_dir.join(format!("{stem}.csv")), &curves)?;
    Ok(ascii_chart(title, "total model bits", metric.label(), &curves, 68, 16))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(family: &str, spec: &str, bits: f64, zs: f64, ce: f64) -> CellResult {
        CellResult {
            key: format!("{family}|{spec}|{bits}"),
            family: family.into(),
            tier: "t0".into(),
            spec_key: spec.into(),
            suite: "ppl_zs".into(),
            ce,
            ppl: ce.exp(),
            zs_acc: vec![zs; 4],
            zs_mean: zs,
            top1: 0.1,
            total_bits: bits,
            bits_per_param: 4.25,
            param_count: 1000,
            wall_s: 0.1,
        }
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(spec_bits("fp:4:b64"), Some(4));
        assert_eq!(spec_bits("fp:16:bnone"), Some(16));
        assert_eq!(spec_dtype("quantile:3:b64"), "quantile");
        assert_eq!(spec_block("fp:4:b64"), Some(64));
        assert_eq!(spec_block("fp:4:bnone"), None);
        assert!(spec_has_proxy("fp:4:b64:p0.02"));
        assert!(!spec_has_proxy("fp:4:b64"));
    }

    #[test]
    fn curves_group_by_precision() {
        let rs = vec![
            result("optlike", "fp:4:b64", 1e6, 0.5, 2.0),
            result("optlike", "fp:4:b64", 1e7, 0.6, 1.8),
            result("optlike", "fp:3:b64", 8e5, 0.4, 2.5),
            result("optlike", "fp:3:b64", 8e6, 0.5, 2.2),
            result("gpt2like", "fp:4:b64", 1e6, 0.9, 1.0), // filtered out
        ];
        let curves = bit_curves(&rs, Some("optlike"));
        assert_eq!(curves.len(), 2);
        let labels: Vec<&str> = curves.iter().map(|c| c.label.as_str()).collect();
        assert!(labels.contains(&"4-bit") && labels.contains(&"3-bit"));
        for c in &curves {
            assert_eq!(c.points().len(), 2);
        }
    }

    #[test]
    fn singleton_groups_are_dropped() {
        let rs = vec![result("optlike", "fp:4:b64", 1e6, 0.5, 2.0)];
        assert!(bit_curves(&rs, None).is_empty());
    }

    #[test]
    fn proxy_results_excluded_from_bit_curves() {
        let rs = vec![
            result("optlike", "fp:3:b64:p0.02", 1e6, 0.5, 2.0),
            result("optlike", "fp:3:b64:p0.02", 1e7, 0.6, 1.8),
        ];
        assert!(bit_curves(&rs, None).is_empty());
    }
}
