//! Pipeline-sharded execution plans.
//!
//! The pre-plan execution path assumed one monolithic AOT graph per tier
//! (`Runtime::load` → a single [`Executable`]), which caps the model size
//! one executable can host. An [`ExecutionPlan`] generalizes that: an
//! ordered list of **stages**, each an HLO artifact with typed
//! inputs/outputs, chained by activation handoff. Every stage is lowered
//! with the uniform calling convention
//!
//! ```text
//! stage_i(stage params…, carried…, tokens, mask) -> carried'
//! ```
//!
//! where `carried` is the previous stage's output tuple (empty for stage
//! 0) and the final stage returns the usual `(nll_sum, top1_hits)` pair.
//! The monolithic graph is the degenerate single-stage plan, so one
//! engine serves both shapes and the sweep/serving layers no longer know
//! about raw executables.
//!
//! [`PlanLayout`] is the compile-free half: stage parameter references
//! from the manifest resolved into concrete shapes and flat-parameter
//! indices (unit-testable without artifacts). [`ExecutionPlan`] adds the
//! compiled executables, reusing the runtime's per-artifact single-flight
//! cache — and is the drop-in point for a GPU/TPU PJRT client: stages
//! compile per device with no layer above this module changing.
//!
//! Stage parameters may be leading-axis **slices** of stacked checkpoint
//! tensors (`lo..hi` layer ranges), so a sharded plan holds each weight
//! exactly once per owning stage; the tied LM head replicates `embed`
//! into the final stage, as real pipeline deployments do.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::{lit_f32_slice, Executable, Runtime};
use crate::models::manifest::{Manifest, TierManifest};
use crate::tensor::Tensor;

/// One resolved plan parameter: a tier checkpoint tensor, optionally
/// sliced along its leading (layer) axis, owned by one stage.
#[derive(Debug, Clone)]
pub struct PlanParam {
    /// Source tier parameter name (e.g. `qkv`).
    pub source: String,
    /// Leading-axis layer range `[lo, hi)`; `None` = the whole tensor.
    pub layers: Option<(usize, usize)>,
    /// Shape after slicing.
    pub shape: Vec<usize>,
    /// Owning stage index.
    pub stage: usize,
}

impl PlanParam {
    /// Display name: `s0/qkv[0..2]` for slices, plain source otherwise.
    pub fn label(&self, stage_name: &str) -> String {
        match self.layers {
            Some((lo, hi)) => format!("{stage_name}/{}[{lo}..{hi}]", self.source),
            None => format!("{stage_name}/{}", self.source),
        }
    }

    /// Element count of the (sliced) parameter.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Borrow the slice of `t` this parameter covers (the whole data for
    /// unsliced params). Validates the source tensor's geometry.
    pub fn slice_of<'t>(&self, t: &'t Tensor) -> Result<&'t [f32]> {
        match self.layers {
            None => {
                ensure!(
                    t.len() == self.numel(),
                    "param {}: checkpoint has {} elements, plan expects {}",
                    self.source,
                    t.len(),
                    self.numel()
                );
                Ok(t.data())
            }
            Some((lo, hi)) => {
                let Some(&l) = t.shape().first() else {
                    bail!("param {}: cannot layer-slice a scalar", self.source)
                };
                ensure!(
                    hi <= l && lo < hi,
                    "param {}: layer range {lo}..{hi} out of bounds for {l} layers",
                    self.source
                );
                let per = t.len() / l.max(1);
                Ok(&t.data()[lo * per..hi * per])
            }
        }
    }
}

/// The compile-free description of one stage: its artifact file, the
/// range of plan parameters it owns, and its output arity.
#[derive(Debug, Clone)]
pub struct PlanStage {
    pub name: String,
    pub hlo: String,
    /// `[lo, hi)` range into [`PlanLayout::params`] (plan parameters are
    /// listed stage by stage, so each stage's share is contiguous).
    pub params: (usize, usize),
    /// Output leaves (carried into the next stage; final stage: 2).
    pub outputs: usize,
}

/// Shape/index resolution of a plan against a tier — everything except
/// the compiled executables, so validation is testable without artifacts.
#[derive(Debug, Clone)]
pub struct PlanLayout {
    pub tier: String,
    pub params: Vec<PlanParam>,
    pub stages: Vec<PlanStage>,
}

impl PlanLayout {
    /// The degenerate single-stage plan every tier supports: the
    /// monolithic `fwd` graph taking all tier parameters.
    pub fn monolithic(tier: &TierManifest) -> PlanLayout {
        let params = tier
            .params
            .iter()
            .map(|p| PlanParam {
                source: p.name.clone(),
                layers: None,
                shape: p.shape.clone(),
                stage: 0,
            })
            .collect::<Vec<_>>();
        let n = params.len();
        PlanLayout {
            tier: tier.name.clone(),
            params,
            stages: vec![PlanStage {
                name: "fwd".into(),
                hlo: tier.fwd_hlo.clone(),
                params: (0, n),
                outputs: 2,
            }],
        }
    }

    /// Resolve the tier's declared pipeline stages into a layout.
    /// Validates stage parameter references, slice bounds, and output
    /// arities; errors here are manifest bugs, not runtime states.
    pub fn staged(tier: &TierManifest) -> Result<PlanLayout> {
        if tier.stages.is_empty() {
            bail!(
                "tier {} declares no pipeline stages (pre-v3 artifacts?); \
                 rerun `make artifacts` or use the monolithic plan",
                tier.name
            );
        }
        let mut params = Vec::new();
        let mut stages = Vec::new();
        for (si, st) in tier.stages.iter().enumerate() {
            ensure!(st.outputs >= 1, "stage {} declares no outputs", st.name);
            let lo = params.len();
            for r in &st.params {
                let info = tier
                    .params
                    .iter()
                    .find(|p| p.name == r.source)
                    .with_context(|| {
                        format!("stage {} references unknown param {:?}", st.name, r.source)
                    })?;
                let shape = match r.layers {
                    None => info.shape.clone(),
                    Some((a, b)) => {
                        let Some(&l) = info.shape.first() else {
                            bail!("stage {}: cannot layer-slice scalar {:?}", st.name, r.source)
                        };
                        ensure!(
                            a < b && b <= l,
                            "stage {}: {:?} layer range {a}..{b} out of bounds for {l}",
                            st.name,
                            r.source
                        );
                        let mut s = info.shape.clone();
                        s[0] = b - a;
                        s
                    }
                };
                params.push(PlanParam {
                    source: r.source.clone(),
                    layers: r.layers,
                    shape,
                    stage: si,
                });
            }
            stages.push(PlanStage {
                name: st.name.clone(),
                hlo: st.hlo.clone(),
                params: (lo, params.len()),
                outputs: st.outputs,
            });
        }
        let last = stages.last().expect("non-empty stages");
        ensure!(
            last.outputs == 2,
            "final stage {} must return (nll, hits), declares {} outputs",
            last.name,
            last.outputs
        );
        Ok(PlanLayout { tier: tier.name.clone(), params, stages })
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Whether this is the degenerate monolithic plan.
    pub fn is_monolithic(&self) -> bool {
        self.stages.len() == 1
    }

    /// Build the flat parameter-literal vector for this layout from a
    /// tier checkpoint (name → tensor pairs in any order). Sliced
    /// parameters borrow the source tensor's contiguous layer range — no
    /// intermediate `Tensor` copies.
    pub fn param_literals<T: std::borrow::Borrow<Tensor>>(
        &self,
        checkpoint: &[(String, T)],
    ) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .map(|p| {
                let (_, t) = checkpoint
                    .iter()
                    .find(|(n, _)| n == &p.source)
                    .with_context(|| format!("checkpoint missing param {:?}", p.source))?;
                lit_f32_slice(&p.shape, p.slice_of(t.borrow())?)
            })
            .collect()
    }
}

/// A compiled plan: the layout plus one executable per stage.
pub struct ExecutionPlan {
    pub layout: PlanLayout,
    exes: Vec<Arc<Executable>>,
}

impl ExecutionPlan {
    /// Compile a plan for `tier`: the declared pipeline stages when
    /// `pipeline` is set, the monolithic single-stage plan otherwise.
    /// Stage artifacts go through the runtime's per-artifact cache, so
    /// plans sharing a stage (or repeated compiles of one tier) reuse
    /// compilations.
    pub fn compile(
        rt: &Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        pipeline: bool,
    ) -> Result<ExecutionPlan> {
        let layout =
            if pipeline { PlanLayout::staged(tier)? } else { PlanLayout::monolithic(tier) };
        let exes = layout
            .stages
            .iter()
            .map(|s| rt.load(&manifest.hlo_path(&s.hlo)))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecutionPlan { layout, exes })
    }

    /// Run the plan on one batch: each stage gets its own parameter
    /// literals, the previous stage's outputs (activation handoff), and
    /// the shared `tokens`/`mask` literals; returns the final stage's
    /// `(nll, hits)` leaves.
    pub fn execute(
        &self,
        rt: &Runtime,
        plits: &[xla::Literal],
        tokens: &xla::Literal,
        mask: &xla::Literal,
    ) -> Result<Vec<xla::Literal>> {
        ensure!(
            plits.len() == self.layout.params.len(),
            "plan {} wants {} parameter literals, got {}",
            self.layout.tier,
            self.layout.params.len(),
            plits.len()
        );
        let mut carried: Vec<xla::Literal> = Vec::new();
        for (stage, exe) in self.layout.stages.iter().zip(&self.exes) {
            let (lo, hi) = stage.params;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(hi - lo + carried.len() + 2);
            args.extend(plits[lo..hi].iter());
            args.extend(carried.iter());
            args.push(tokens);
            args.push(mask);
            let out = rt
                .execute(exe, &args)
                .with_context(|| format!("executing plan stage {}", stage.name))?;
            ensure!(
                out.len() == stage.outputs,
                "stage {} returned {} leaves, expected {}",
                stage.name,
                out.len(),
                stage.outputs
            );
            carried = out;
        }
        Ok(carried)
    }

    /// Build the flat parameter-literal vector from a tier checkpoint
    /// (see [`PlanLayout::param_literals`]).
    pub fn param_literals<T: std::borrow::Borrow<Tensor>>(
        &self,
        checkpoint: &[(String, T)],
    ) -> Result<Vec<xla::Literal>> {
        self.layout.param_literals(checkpoint)
    }
}

#[cfg(test)]
mod tests {
    //! Layout resolution is artifact-free; staged *execution* is covered
    //! by the integration suite (`rust/tests/`).
    use super::*;
    use crate::models::manifest::{ParamInfo, StageManifest, StageParamRef};

    fn tier_with_stages(stages: Vec<StageManifest>) -> TierManifest {
        TierManifest {
            name: "t0".into(),
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 128,
            vocab: 512,
            seq: 64,
            batch_train: 8,
            batch_eval: 16,
            param_count: 0,
            params: vec![
                ParamInfo { name: "embed".into(), shape: vec![512, 32] },
                ParamInfo { name: "qkv".into(), shape: vec![2, 32, 96] },
            ],
            quantized_params: vec!["qkv".into()],
            fwd_hlo: "fwd_t0.hlo.txt".into(),
            train_hlo: "train_t0.hlo.txt".into(),
            acts_hlo: None,
            stages,
        }
    }

    fn two_stage() -> Vec<StageManifest> {
        vec![
            StageManifest {
                name: "s0".into(),
                hlo: "fwd_a_t0.hlo.txt".into(),
                outputs: 1,
                params: vec![
                    StageParamRef { source: "embed".into(), layers: None },
                    StageParamRef { source: "qkv".into(), layers: Some((0, 1)) },
                ],
            },
            StageManifest {
                name: "s1".into(),
                hlo: "fwd_b_t0.hlo.txt".into(),
                outputs: 2,
                params: vec![
                    StageParamRef { source: "qkv".into(), layers: Some((1, 2)) },
                    StageParamRef { source: "embed".into(), layers: None },
                ],
            },
        ]
    }

    #[test]
    fn monolithic_layout_mirrors_tier_params() {
        let tier = tier_with_stages(vec![]);
        let l = PlanLayout::monolithic(&tier);
        assert!(l.is_monolithic());
        assert_eq!(l.params.len(), 2);
        assert_eq!(l.stages[0].params, (0, 2));
        assert_eq!(l.stages[0].outputs, 2);
        assert_eq!(l.params[1].shape, vec![2, 32, 96]);
    }

    #[test]
    fn staged_layout_slices_and_replicates() {
        let tier = tier_with_stages(two_stage());
        let l = PlanLayout::staged(&tier).unwrap();
        assert_eq!(l.n_stages(), 2);
        assert!(!l.is_monolithic());
        // Sliced stacked tensor: leading dim replaced by the range width.
        assert_eq!(l.params[1].shape, vec![1, 32, 96]);
        assert_eq!(l.params[2].shape, vec![1, 32, 96]);
        // embed is replicated (tied head) — once per owning stage.
        let embeds: Vec<usize> =
            l.params.iter().filter(|p| p.source == "embed").map(|p| p.stage).collect();
        assert_eq!(embeds, vec![0, 1]);
        // Contiguous per-stage ranges.
        assert_eq!(l.stages[0].params, (0, 2));
        assert_eq!(l.stages[1].params, (2, 4));
        assert_eq!(l.params[0].label("s0"), "s0/embed");
        assert_eq!(l.params[1].label("s0"), "s0/qkv[0..1]");
    }

    #[test]
    fn staged_layout_rejects_bad_manifests() {
        // No stages declared.
        assert!(PlanLayout::staged(&tier_with_stages(vec![])).is_err());
        // Unknown source param.
        let mut bad = two_stage();
        bad[0].params[0].source = "nope".into();
        assert!(PlanLayout::staged(&tier_with_stages(bad)).is_err());
        // Slice out of bounds.
        let mut bad = two_stage();
        bad[1].params[0].layers = Some((1, 3));
        assert!(PlanLayout::staged(&tier_with_stages(bad)).is_err());
        // Empty slice.
        let mut bad = two_stage();
        bad[0].params[1].layers = Some((1, 1));
        assert!(PlanLayout::staged(&tier_with_stages(bad)).is_err());
        // Final stage must score.
        let mut bad = two_stage();
        bad[1].outputs = 1;
        assert!(PlanLayout::staged(&tier_with_stages(bad)).is_err());
    }

    #[test]
    fn plan_param_slicing_borrows_layer_ranges() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = PlanParam {
            source: "qkv".into(),
            layers: Some((1, 2)),
            shape: vec![1, 3],
            stage: 0,
        };
        assert_eq!(p.slice_of(&t).unwrap(), &[4., 5., 6.]);
        let whole =
            PlanParam { source: "x".into(), layers: None, shape: vec![2, 3], stage: 0 };
        assert_eq!(whole.slice_of(&t).unwrap().len(), 6);
        let bad = PlanParam {
            source: "x".into(),
            layers: Some((2, 3)),
            shape: vec![1, 3],
            stage: 0,
        };
        assert!(bad.slice_of(&t).is_err());
        // Shape mismatch on an unsliced param is caught, not silently fed.
        let wrong =
            PlanParam { source: "x".into(), layers: None, shape: vec![7], stage: 0 };
        assert!(wrong.slice_of(&t).is_err());
    }

    #[test]
    fn layout_param_literals_resolve_by_name() {
        let tier = tier_with_stages(two_stage());
        let l = PlanLayout::staged(&tier).unwrap();
        let embed = Tensor::zeros(vec![512, 32]);
        let qkv = Tensor::zeros(vec![2, 32, 96]);
        // Checkpoint order differs from plan order: resolution is by name.
        let ckpt = vec![("qkv".to_string(), qkv), ("embed".to_string(), embed)];
        let lits = l.param_literals(&ckpt).unwrap();
        assert_eq!(lits.len(), 4);
        // Missing tensors are an error, not a panic.
        assert!(l.param_literals(&ckpt[..1].to_vec()).is_err());
    }
}
