//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! jax ≥ 0.5 emits serialized protos with 64-bit instruction ids that this
//! XLA rejects, while the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §2).
//!
//! Compiled executables are cached per artifact path (single-flight:
//! racing threads compile each artifact once): every sweep cell of a tier
//! reuses one compilation. All graphs are lowered with
//! `return_tuple=True`, so execution unwraps a single tuple literal into
//! its leaves. [`plan`] builds multi-stage execution plans (pipeline
//! sharding) on top of this cache; the monolithic graph is the degenerate
//! single-stage plan.

pub mod native;
pub mod plan;

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{Context, Result};

use crate::tensor::Tensor;

pub use plan::{ExecutionPlan, PlanLayout};

/// Compiled-executable handle, shareable across worker threads.
///
/// SAFETY: the PJRT CPU client is internally synchronized and its
/// executables are immutable after compilation; the `xla` crate just
/// doesn't mark the FFI handles Send/Sync. Execution from multiple threads
/// is the documented PJRT usage model.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

// SAFETY: see the struct docs — PJRT CPU executables are immutable and
// internally synchronized; multi-threaded execution is the documented model.
unsafe impl Send for Executable {}
// SAFETY: same argument as the Send impl above.
unsafe impl Sync for Executable {}

/// The process-wide runtime: one PJRT CPU client + executable cache.
/// Loading is single-flight: racing threads that miss the cache compile
/// each artifact exactly once (mirroring the model registry's pattern).
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
    /// Paths some thread is currently compiling (single-flight loading).
    compiling: Mutex<HashSet<PathBuf>>,
    compiled_cv: Condvar,
}

// SAFETY: the PJRT client is internally synchronized (see [`Executable`]);
// all other Runtime state is behind std Mutex/Condvar.
unsafe impl Send for Runtime {}
// SAFETY: same argument as the Send impl above.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create the CPU runtime. One per process is the intended pattern
    /// (the compilation cache lives here).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            compiling: Mutex::new(HashSet::new()),
            compiled_cv: Condvar::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached, single-flight).
    /// Racing threads that miss the cache compile the artifact exactly
    /// once: one claims the build, the rest block until its executable is
    /// cached and share it.
    pub fn load(&self, path: &Path) -> Result<Arc<Executable>> {
        loop {
            if let Some(hit) = self.cache.lock().unwrap().get(path) {
                return Ok(hit.clone());
            }
            // Claim the compile, or wait for the thread that holds it.
            {
                let mut compiling = self.compiling.lock().unwrap();
                if !compiling.contains(path) {
                    compiling.insert(path.to_path_buf());
                    break;
                }
                while compiling.contains(path) {
                    compiling = self.compiled_cv.wait(compiling).unwrap();
                }
            }
            // The builder finished (or failed): re-check the cache; on
            // failure this thread claims the compile and retries it.
        }
        // Release the claim on every exit path, including compile errors,
        // so waiters never block on a dead flight.
        struct FlightGuard<'g> {
            rt: &'g Runtime,
            path: &'g Path,
        }
        impl Drop for FlightGuard<'_> {
            fn drop(&mut self) {
                self.rt.compiling.lock().unwrap().remove(self.path);
                self.rt.compiled_cv.notify_all();
            }
        }
        let _flight = FlightGuard { rt: self, path };
        // A winner may have inserted between our cache check and the
        // claim; one more look avoids a redundant compile.
        if let Some(hit) = self.cache.lock().unwrap().get(path) {
            return Ok(hit.clone());
        }
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::info!("compiled {} in {:.2}s", path.display(), t.elapsed().as_secs_f64());
        let arc = Arc::new(Executable { exe, path: path.to_path_buf() });
        self.cache.lock().unwrap().insert(path.to_path_buf(), arc.clone());
        Ok(arc)
    }

    /// Execute with literal arguments (owned or borrowed — parameter
    /// literals are typically built once per cell and passed by reference
    /// across batches); returns the tuple leaves.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        exe: &Executable,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let buffers = exe
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", exe.path.display()))?;
        let result = buffers[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // All our graphs are lowered with return_tuple=True.
        let leaves = result.to_tuple().context("untupling result")?;
        Ok(leaves)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

fn dims_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}

/// f32 tensor → literal (reshaped to the tensor's shape).
pub fn lit_f32(t: &Tensor) -> Result<xla::Literal> {
    lit_f32_slice(t.shape(), t.data())
}

/// f32 slice + shape → literal, with no intermediate `Tensor`. The serving
/// path streams packed weights through one reusable scratch buffer and
/// builds each parameter literal straight from it.
pub fn lit_f32_slice(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    Ok(flat.reshape(&dims_i64(shape))?)
}

/// The resident parameter literals of one loaded model, shareable across
/// serving threads.
///
/// SAFETY: same argument as [`Executable`] — the literals are immutable
/// after construction, execution only reads them, and the PJRT CPU client
/// is internally synchronized; the `xla` crate just doesn't mark the FFI
/// handles Send/Sync.
pub struct ParamLiterals(pub Vec<xla::Literal>);

// SAFETY: see the struct docs — literals are immutable after construction
// and PJRT execution only reads them.
unsafe impl Send for ParamLiterals {}
// SAFETY: same argument as the Send impl above.
unsafe impl Sync for ParamLiterals {}

/// i32 data → literal of `shape`.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    Ok(flat.reshape(&dims_i64(shape))?)
}

/// u8 data → literal of `shape` (the crate has no `vec1` for u8; build
/// from untyped bytes instead).
pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<xla::Literal> {
    anyhow::ensure!(shape.iter().product::<usize>() == data.len(), "shape/data mismatch");
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        shape,
        data,
    )?)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal → owned f32 vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Literal → Tensor with the caller-known shape.
pub fn to_tensor(lit: &xla::Literal, shape: Vec<usize>) -> Result<Tensor> {
    let data = to_vec_f32(lit)?;
    Ok(Tensor::new(shape, data))
}

#[cfg(test)]
mod tests {
    //! Executable loading/execution is covered by the integration suite
    //! (`rust/tests/`), which requires built artifacts. The literal
    //! helpers are unit-testable standalone.
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = lit_f32(&t).unwrap();
        let back = to_tensor(&lit, vec![2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_i32_shape_validation() {
        assert!(lit_i32(&[2, 2], &[1, 2, 3]).is_err());
        let l = lit_i32(&[2, 2], &[1, 2, 3, 4]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn literal_u8_roundtrip() {
        let l = lit_u8(&[4], &[7, 0, 255, 3]).unwrap();
        assert_eq!(l.to_vec::<u8>().unwrap(), vec![7, 0, 255, 3]);
    }

    #[test]
    fn scalar_literal() {
        let l = lit_scalar(2.5);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn slice_literal_matches_tensor_literal() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let a = lit_f32(&t).unwrap();
        let b = lit_f32_slice(&[2, 2], &[1., 2., 3., 4.]).unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert!(lit_f32_slice(&[2, 2], &[1., 2., 3.]).is_err());
    }

    #[test]
    fn param_literals_are_shareable() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ParamLiterals>();
    }
}
