//! Native fused-kernel execution backend.
//!
//! The XLA path dequantizes every packed parameter into a full f32 literal
//! at load time and scores through AOT graphs. This module is the
//! `{"op":"load","fused":true}` alternative: a pure-Rust forward pass whose
//! projection matmuls walk [`PackedParam`] residency directly through
//! [`crate::quant::fused`] — packed weights never expand to full f32
//! tensors, at load time or on the score path. With `"entropy":true` the
//! same matmuls stream-decode [`EncodedParam`] Huffman residency through
//! [`crate::quant::entropy::fused_matmul_encoded`] instead, losslessly —
//! scores stay bit-identical to the packed variant. Unquantized parameters
//! (embeddings, LayerNorms, baseline stages of a mixed-precision plan)
//! stay dense f32, exactly as the paper prescribes.
//!
//! A [`NativeModel`] is built from the same [`PlanLayout`] the XLA path
//! compiles, so monolithic and pipeline-sharded variants both resolve here:
//! stage-sliced stacked tensors are reassembled per layer, and because
//! [`PackedParam`] quantizes leading-axis slices independently, a sharded
//! build's weights are bit-identical to the monolithic build under the same
//! spec — the fused score of either plan shape is the same number.
//!
//! Scoring semantics mirror `python/compile/model.py` (`eval_scores`):
//! pre-LN blocks, causal softmax attention, tanh-approximate GELU, tied LM
//! head, masked NLL sums + greedy top-1 hits per row. Agreement with the
//! XLA executables is to float tolerance (operation order differs inside
//! XLA's fusions); agreement between the scalar and SIMD fused paths is
//! exact (see `quant::fused`).
//!
//! Projection matmuls run **column-parallel** across the scoped worker
//! pool ([`crate::quant::fused::fused_matmul_parallel`]): the worker count
//! is latched from `KBITSCALE_THREADS` at build time
//! ([`crate::util::pool::scoring_threads`]), and because each output
//! column is owned by exactly one thread with an unchanged accumulation
//! order, scores are bit-identical at every thread count — the
//! `set_threads` override exists so tests and benches can pin 1/2/4-way
//! runs against each other.

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use super::plan::PlanLayout;
use crate::models::manifest::TierManifest;
use crate::quant::{entropy, fused};
use crate::quant::{EncodedParam, PackedParam};
use crate::util::pool;

/// One plan parameter in native residency: packed k-bit indices for
/// quantized tensors (or their entropy-coded twin under
/// `{"op":"load","fused":true,"entropy":true}`), dense f32 for everything
/// else. Entries are given in [`PlanLayout::params`] order.
pub enum NativeParam {
    Dense(Vec<f32>),
    Packed(Arc<PackedParam>),
    Encoded(Arc<EncodedParam>),
}

/// One layer's projection weight: a slice view into a shared dense buffer,
/// or one leading-axis slice of a shared packed/encoded parameter.
#[derive(Clone)]
enum Mat {
    /// (storage, element offset of this layer's `[k, n]` block).
    Dense(Arc<Vec<f32>>, usize),
    /// (packed parameter, leading-axis slice index).
    Packed(Arc<PackedParam>, usize),
    /// (entropy-coded parameter, leading-axis slice index).
    Encoded(Arc<EncodedParam>, usize),
}

/// Per-layer weights, reassembled from (possibly stage-sliced) plan params.
struct Layer {
    qkv: Mat,
    wo: Mat,
    fc1: Mat,
    fc2: Mat,
    ln1_s: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_s: Vec<f32>,
    ln2_b: Vec<f32>,
}

/// A resident model variant executable natively through the fused kernel.
pub struct NativeModel {
    d: usize,
    n_layer: usize,
    n_head: usize,
    d_ff: usize,
    vocab: usize,
    seq: usize,
    batch_eval: usize,
    embed: Vec<f32>,
    pos: Vec<f32>,
    layers: Vec<Layer>,
    lnf_s: Vec<f32>,
    lnf_b: Vec<f32>,
    /// Column-parallel matmul worker count (see module docs).
    threads: usize,
}

/// Internal: a plan parameter promoted to shareable storage.
enum Entry {
    Dense(Arc<Vec<f32>>),
    Packed(Arc<PackedParam>),
    Encoded(Arc<EncodedParam>),
}

impl NativeModel {
    /// Assemble a native model from a plan layout and its parameters (in
    /// `layout.params` order — the exact order `ModelHandle::with_plan`
    /// walks). Validates geometry against the tier manifest; errors here
    /// are build-time, never mid-score.
    pub fn build(
        tier: &TierManifest,
        layout: &PlanLayout,
        params: Vec<NativeParam>,
    ) -> Result<NativeModel> {
        ensure!(
            params.len() == layout.params.len(),
            "native build: {} params for a {}-param layout",
            params.len(),
            layout.params.len()
        );
        let (d, l, f) = (tier.d_model, tier.n_layer, tier.d_ff);
        ensure!(tier.n_head > 0 && d % tier.n_head == 0, "d_model must divide by n_head");
        let entries: Vec<Entry> = params
            .into_iter()
            .map(|p| match p {
                NativeParam::Dense(v) => Entry::Dense(Arc::new(v)),
                NativeParam::Packed(a) => Entry::Packed(a),
                NativeParam::Encoded(a) => Entry::Encoded(a),
            })
            .collect();
        let qkv = layer_mats(layout, &entries, "qkv", l, d * 3 * d)?;
        let wo = layer_mats(layout, &entries, "wo", l, d * d)?;
        let fc1 = layer_mats(layout, &entries, "fc1", l, d * f)?;
        let fc2 = layer_mats(layout, &entries, "fc2", l, f * d)?;
        let ln1_s = layer_vecs(layout, &entries, "ln1_s", l, d)?;
        let ln1_b = layer_vecs(layout, &entries, "ln1_b", l, d)?;
        let ln2_s = layer_vecs(layout, &entries, "ln2_s", l, d)?;
        let ln2_b = layer_vecs(layout, &entries, "ln2_b", l, d)?;
        let layers = (0..l)
            .map(|li| Layer {
                qkv: qkv[li].clone(),
                wo: wo[li].clone(),
                fc1: fc1[li].clone(),
                fc2: fc2[li].clone(),
                ln1_s: ln1_s[li].clone(),
                ln1_b: ln1_b[li].clone(),
                ln2_s: ln2_s[li].clone(),
                ln2_b: ln2_b[li].clone(),
            })
            .collect();
        Ok(NativeModel {
            d,
            n_layer: l,
            n_head: tier.n_head,
            d_ff: f,
            vocab: tier.vocab,
            seq: tier.seq,
            batch_eval: tier.batch_eval.max(1),
            embed: whole_dense(layout, &entries, "embed", tier.vocab * d)?,
            pos: whole_dense(layout, &entries, "pos", tier.seq * d)?,
            layers,
            lnf_s: whole_dense(layout, &entries, "lnf_s", d)?,
            lnf_b: whole_dense(layout, &entries, "lnf_b", d)?,
            threads: pool::scoring_threads(),
        })
    }

    /// Worker threads the projection matmuls fan columns across.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Override the scoring thread count. Serving builds latch
    /// [`pool::scoring_threads`] (`KBITSCALE_THREADS`); this setter lets
    /// tests and benches pin explicit 1/2/4-way runs — which are
    /// bit-identical by construction.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Score padded `(tokens, mask)` rows: per-row `(nll_sum, top1_hits)`,
    /// the same contract as the XLA plan. Rows are chunked by the tier's
    /// eval batch internally.
    pub fn score_rows(&self, rows: &[(Vec<i32>, Vec<f32>)]) -> Result<Vec<(f64, f64)>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch_eval) {
            self.score_chunk(chunk, &mut out)?;
        }
        Ok(out)
    }

    fn score_chunk(&self, rows: &[(Vec<i32>, Vec<f32>)], out: &mut Vec<(f64, f64)>) -> Result<()> {
        let (b, s, d, f) = (rows.len(), self.seq, self.d, self.d_ff);
        for (t, m) in rows {
            ensure!(t.len() == s && m.len() == s, "rows must be padded to seq {s}");
            if let Some(&bad) = t.iter().find(|&&v| v < 0 || v as usize >= self.vocab) {
                bail!("token {bad} out of vocab range 0..{}", self.vocab);
            }
        }
        // Embed + positional.
        let mut x = vec![0.0f32; b * s * d];
        for (r, (toks, _)) in rows.iter().enumerate() {
            for (p, &tok) in toks.iter().enumerate() {
                let dst = (r * s + p) * d;
                let emb = &self.embed[tok as usize * d..(tok as usize + 1) * d];
                let pe = &self.pos[p * d..(p + 1) * d];
                for j in 0..d {
                    x[dst + j] = emb[j] + pe[j];
                }
            }
        }
        let (h, hd) = (self.n_head, d / self.n_head);
        let rows_bs = b * s;
        let mut y = vec![0.0f32; rows_bs * d];
        let mut qkv_out = vec![0.0f32; rows_bs * 3 * d];
        let mut att_out = vec![0.0f32; rows_bs * d];
        let mut proj = vec![0.0f32; rows_bs * d];
        let mut ff = vec![0.0f32; rows_bs * f];
        let mut att_row = vec![0.0f32; s];
        let mut panel = Vec::new();
        for layer in &self.layers {
            // Attention sub-block (pre-LN).
            layernorm(&x, &layer.ln1_s, &layer.ln1_b, &mut y, d);
            qkv_out.iter_mut().for_each(|v| *v = 0.0);
            apply_mat(&layer.qkv, &y, &mut qkv_out, rows_bs, d, 3 * d, self.threads, &mut panel)?;
            att_out.iter_mut().for_each(|v| *v = 0.0);
            let scale = 1.0 / (hd as f32).sqrt();
            for bi in 0..b {
                for hi in 0..h {
                    for t in 0..s {
                        let q = &qkv_out[(bi * s + t) * 3 * d + hi * hd..][..hd];
                        // Causal scores over positions 0..=t, softmaxed.
                        let mut maxv = f32::NEG_INFINITY;
                        for (u, a) in att_row.iter_mut().enumerate().take(t + 1) {
                            let k = &qkv_out[(bi * s + u) * 3 * d + d + hi * hd..][..hd];
                            let mut dot = 0.0f32;
                            for j in 0..hd {
                                dot += q[j] * k[j];
                            }
                            *a = dot * scale;
                            maxv = maxv.max(*a);
                        }
                        let mut denom = 0.0f32;
                        for a in att_row.iter_mut().take(t + 1) {
                            *a = (*a - maxv).exp();
                            denom += *a;
                        }
                        let dst = (bi * s + t) * d + hi * hd;
                        for u in 0..=t {
                            let p = att_row[u] / denom;
                            let v = &qkv_out[(bi * s + u) * 3 * d + 2 * d + hi * hd..][..hd];
                            for j in 0..hd {
                                att_out[dst + j] += p * v[j];
                            }
                        }
                    }
                }
            }
            proj.iter_mut().for_each(|v| *v = 0.0);
            apply_mat(&layer.wo, &att_out, &mut proj, rows_bs, d, d, self.threads, &mut panel)?;
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }
            // MLP sub-block.
            layernorm(&x, &layer.ln2_s, &layer.ln2_b, &mut y, d);
            ff.iter_mut().for_each(|v| *v = 0.0);
            apply_mat(&layer.fc1, &y, &mut ff, rows_bs, d, f, self.threads, &mut panel)?;
            for v in ff.iter_mut() {
                *v = gelu_tanh(*v);
            }
            proj.iter_mut().for_each(|v| *v = 0.0);
            apply_mat(&layer.fc2, &ff, &mut proj, rows_bs, f, d, self.threads, &mut panel)?;
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }
        }
        layernorm(&x, &self.lnf_s, &self.lnf_b, &mut y, d);
        // Tied LM head + masked scoring, one position at a time (the full
        // (B, S, V) logits tensor is never materialized).
        let mut logits = vec![0.0f32; self.vocab];
        for (r, (toks, mask)) in rows.iter().enumerate() {
            let mut nll = 0.0f64;
            let mut hits = 0.0f64;
            for t in 0..s - 1 {
                let mw = mask[t + 1];
                if mw == 0.0 {
                    continue; // zero-weight target contributes exactly 0
                }
                let target = toks[t + 1] as usize;
                let hrow = &y[(r * s + t) * d..(r * s + t + 1) * d];
                for (v, lg) in logits.iter_mut().enumerate() {
                    let erow = &self.embed[v * d..(v + 1) * d];
                    let mut dot = 0.0f32;
                    for j in 0..d {
                        dot += hrow[j] * erow[j];
                    }
                    *lg = dot;
                }
                // First-max argmax (JAX tie-breaking) + log-sum-exp.
                let mut best = 0usize;
                let mut maxv = logits[0];
                for (v, &lg) in logits.iter().enumerate().skip(1) {
                    if lg > maxv {
                        maxv = lg;
                        best = v;
                    }
                }
                let mut denom = 0.0f32;
                for &lg in &logits {
                    denom += (lg - maxv).exp();
                }
                let logp = (logits[target] - maxv) - denom.ln();
                nll -= logp as f64 * mw as f64;
                if best == target {
                    hits += mw as f64;
                }
            }
            out.push((nll, hits));
        }
        Ok(())
    }
}

/// Run one matmul (`out[m,n] += x[m,k] @ W[k,n]`) through the weight's
/// residency form: dense f32 GEMM or the fused packed kernel, fanning
/// output columns across `threads` workers (`<= 1` stays on the calling
/// thread with the caller's `panel` scratch). Entropy-coded weights
/// stream-decode row-by-row on the calling thread — variable-length
/// decode is inherently sequential, so `threads` is ignored there (scores
/// stay bit-identical to the packed fused path either way).
#[allow(clippy::too_many_arguments)]
fn apply_mat(
    mat: &Mat,
    x: &[f32],
    out: &mut [f32],
    m: usize,
    kd: usize,
    n: usize,
    threads: usize,
    panel: &mut Vec<f32>,
) -> Result<()> {
    match mat {
        Mat::Dense(v, off) => {
            fused::matmul_f32_parallel(x, &v[*off..*off + kd * n], out, m, kd, n, threads);
            Ok(())
        }
        Mat::Packed(p, si) => {
            fused::fused_matmul_parallel(x, &p.slices[*si], out, m, kd, n, threads, panel)
        }
        Mat::Encoded(e, si) => {
            if panel.len() < n {
                panel.resize(n, 0.0);
            }
            entropy::fused_matmul_encoded(x, &e.slices[*si], out, m, kd, n, panel)
        }
    }
}

/// LayerNorm rows of `x` (inner dim `d`) into `y` with eps 1e-5.
fn layernorm(x: &[f32], scale: &[f32], bias: &[f32], y: &mut [f32], d: usize) {
    for (xr, yr) in x.chunks_exact(d).zip(y.chunks_exact_mut(d)) {
        let mut mean = 0.0f32;
        for &v in xr {
            mean += v;
        }
        mean /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            var += (v - mean) * (v - mean);
        }
        var /= d as f32;
        let rstd = 1.0 / (var + 1e-5).sqrt();
        for j in 0..d {
            yr[j] = (xr[j] - mean) * rstd * scale[j] + bias[j];
        }
    }
}

/// Tanh-approximate GELU (`jax.nn.gelu`'s default form).
fn gelu_tanh(v: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh())
}

/// Resolve a whole (never layer-sliced, never quantized) dense parameter.
fn whole_dense(
    layout: &PlanLayout,
    entries: &[Entry],
    source: &str,
    numel: usize,
) -> Result<Vec<f32>> {
    for (pp, e) in layout.params.iter().zip(entries) {
        if pp.source != source || pp.layers.is_some() {
            continue;
        }
        let Entry::Dense(v) = e else {
            bail!("param {source} is quantized; expected dense residency");
        };
        ensure!(v.len() == numel, "param {source}: {} elements, expected {numel}", v.len());
        return Ok(v.as_ref().clone());
    }
    Err(anyhow!("layout has no whole dense param {source:?}"))
}

/// Reassemble one layer-stacked projection source into per-layer [`Mat`]s,
/// merging stage slices (`lo..hi` ranges) back into layer order. `per` is
/// one layer's element count.
fn layer_mats(
    layout: &PlanLayout,
    entries: &[Entry],
    source: &str,
    n_layer: usize,
    per: usize,
) -> Result<Vec<Mat>> {
    let mut mats: Vec<Option<Mat>> = vec![None; n_layer];
    for (pp, e) in layout.params.iter().zip(entries) {
        if pp.source != source {
            continue;
        }
        let (lo, hi) = pp.layers.unwrap_or((0, n_layer));
        ensure!(hi <= n_layer && lo < hi, "param {source}: bad layer range {lo}..{hi}");
        match e {
            Entry::Dense(v) => {
                ensure!(
                    v.len() == (hi - lo) * per,
                    "param {source}[{lo}..{hi}]: {} elements, expected {}",
                    v.len(),
                    (hi - lo) * per
                );
                for li in lo..hi {
                    mats[li] = Some(Mat::Dense(v.clone(), (li - lo) * per));
                }
            }
            Entry::Packed(p) => {
                ensure!(
                    p.slices.len() == hi - lo && p.slices.iter().all(|sl| sl.n == per),
                    "param {source}[{lo}..{hi}]: packed slices do not match layer geometry"
                );
                for li in lo..hi {
                    mats[li] = Some(Mat::Packed(p.clone(), li - lo));
                }
            }
            Entry::Encoded(ep) => {
                ensure!(
                    ep.slices.len() == hi - lo && ep.slices.iter().all(|sl| sl.n == per),
                    "param {source}[{lo}..{hi}]: encoded slices do not match layer geometry"
                );
                for li in lo..hi {
                    mats[li] = Some(Mat::Encoded(ep.clone(), li - lo));
                }
            }
        }
    }
    mats.into_iter()
        .enumerate()
        .map(|(li, m)| m.ok_or_else(|| anyhow!("layer {li} of {source:?} missing from layout")))
        .collect()
}

/// Reassemble a layer-stacked dense vector source (LayerNorm scales and
/// biases) into per-layer copies.
fn layer_vecs(
    layout: &PlanLayout,
    entries: &[Entry],
    source: &str,
    n_layer: usize,
    d: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut vecs: Vec<Option<Vec<f32>>> = vec![None; n_layer];
    for (pp, e) in layout.params.iter().zip(entries) {
        if pp.source != source {
            continue;
        }
        let (lo, hi) = pp.layers.unwrap_or((0, n_layer));
        ensure!(hi <= n_layer && lo < hi, "param {source}: bad layer range {lo}..{hi}");
        let Entry::Dense(v) = e else {
            bail!("param {source} is quantized; LayerNorm params stay dense");
        };
        ensure!(
            v.len() == (hi - lo) * d,
            "param {source}[{lo}..{hi}]: {} elements, expected {}",
            v.len(),
            (hi - lo) * d
        );
        for li in lo..hi {
            vecs[li] = Some(v[(li - lo) * d..(li - lo + 1) * d].to_vec());
        }
    }
    vecs.into_iter()
        .enumerate()
        .map(|(li, m)| m.ok_or_else(|| anyhow!("layer {li} of {source:?} missing from layout")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{ParamInfo, StageManifest, StageParamRef, TierManifest};
    use crate::quant::{DataType, QuantSpec};
    use crate::util::rng::Rng;

    const D: usize = 8;
    const L: usize = 2;
    const F: usize = 32;
    const V: usize = 32;
    const S: usize = 8;

    fn tiny_tier(stages: Vec<StageManifest>) -> TierManifest {
        TierManifest {
            name: "tiny".into(),
            d_model: D,
            n_layer: L,
            n_head: 2,
            d_ff: F,
            vocab: V,
            seq: S,
            batch_train: 2,
            batch_eval: 4,
            param_count: 0,
            params: vec![
                ParamInfo { name: "embed".into(), shape: vec![V, D] },
                ParamInfo { name: "pos".into(), shape: vec![S, D] },
                ParamInfo { name: "qkv".into(), shape: vec![L, D, 3 * D] },
                ParamInfo { name: "wo".into(), shape: vec![L, D, D] },
                ParamInfo { name: "fc1".into(), shape: vec![L, D, F] },
                ParamInfo { name: "fc2".into(), shape: vec![L, F, D] },
                ParamInfo { name: "ln1_s".into(), shape: vec![L, D] },
                ParamInfo { name: "ln1_b".into(), shape: vec![L, D] },
                ParamInfo { name: "ln2_s".into(), shape: vec![L, D] },
                ParamInfo { name: "ln2_b".into(), shape: vec![L, D] },
                ParamInfo { name: "lnf_s".into(), shape: vec![D] },
                ParamInfo { name: "lnf_b".into(), shape: vec![D] },
            ],
            quantized_params: vec!["qkv".into(), "wo".into(), "fc1".into(), "fc2".into()],
            fwd_hlo: "fwd_tiny.hlo.txt".into(),
            train_hlo: "train_tiny.hlo.txt".into(),
            acts_hlo: None,
            stages,
        }
    }

    fn two_stages() -> Vec<StageManifest> {
        let sliced = |source: &str, lo, hi| StageParamRef {
            source: source.into(),
            layers: Some((lo, hi)),
        };
        vec![
            StageManifest {
                name: "s0".into(),
                hlo: "a.hlo.txt".into(),
                outputs: 1,
                params: vec![
                    StageParamRef { source: "embed".into(), layers: None },
                    StageParamRef { source: "pos".into(), layers: None },
                    sliced("qkv", 0, 1),
                    sliced("wo", 0, 1),
                    sliced("fc1", 0, 1),
                    sliced("fc2", 0, 1),
                    sliced("ln1_s", 0, 1),
                    sliced("ln1_b", 0, 1),
                    sliced("ln2_s", 0, 1),
                    sliced("ln2_b", 0, 1),
                ],
            },
            StageManifest {
                name: "s1".into(),
                hlo: "b.hlo.txt".into(),
                outputs: 2,
                params: vec![
                    sliced("qkv", 1, 2),
                    sliced("wo", 1, 2),
                    sliced("fc1", 1, 2),
                    sliced("fc2", 1, 2),
                    sliced("ln1_s", 1, 2),
                    sliced("ln1_b", 1, 2),
                    sliced("ln2_s", 1, 2),
                    sliced("ln2_b", 1, 2),
                    StageParamRef { source: "lnf_s".into(), layers: None },
                    StageParamRef { source: "lnf_b".into(), layers: None },
                    StageParamRef { source: "embed".into(), layers: None },
                ],
            },
        ]
    }

    fn checkpoint(seed: u64, tier: &TierManifest) -> Vec<(String, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        tier.params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                let mut v = vec![0.0f32; n];
                if p.name.ends_with("_s") {
                    v.iter_mut().for_each(|x| *x = 1.0);
                } else {
                    rng.fill_normal(&mut v, 0.1);
                }
                (p.name.clone(), v)
            })
            .collect()
    }

    /// Build a NativeModel over `layout`: quantized sources packed under
    /// `spec` when `packed` is set, otherwise dense with the **dequantized**
    /// weights — the two residency forms of identical numbers.
    fn build_native(
        tier: &TierManifest,
        layout: &PlanLayout,
        ckpt: &[(String, Vec<f32>)],
        spec: &QuantSpec,
        packed: bool,
    ) -> NativeModel {
        let params: Vec<NativeParam> = layout
            .params
            .iter()
            .map(|pp| {
                let (_, data) = ckpt.iter().find(|(n, _)| n == &pp.source).unwrap();
                let per: usize = pp.shape.iter().skip(1).product::<usize>().max(1);
                let slice = match pp.layers {
                    Some((lo, hi)) => &data[lo * per..hi * per],
                    None => &data[..],
                };
                if tier.quantized_params.iter().any(|q| q == &pp.source) {
                    let pk = PackedParam::quantize_slice(&pp.shape, slice, spec).unwrap();
                    if packed {
                        NativeParam::Packed(std::sync::Arc::new(pk))
                    } else {
                        let mut dq = vec![0.0f32; slice.len()];
                        pk.dequantize_into(&mut dq).unwrap();
                        NativeParam::Dense(dq)
                    }
                } else {
                    NativeParam::Dense(slice.to_vec())
                }
            })
            .collect();
        NativeModel::build(tier, layout, params).unwrap()
    }

    fn score_input(seed: u64, n_rows: usize) -> Vec<(Vec<i32>, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..n_rows)
            .map(|_| {
                let toks: Vec<i32> = (0..S).map(|_| rng.below(V) as i32).collect();
                let mask: Vec<f32> =
                    (0..S).map(|i| if i > 0 && rng.below(4) > 0 { 1.0 } else { 0.0 }).collect();
                (toks, mask)
            })
            .collect()
    }

    #[test]
    fn packed_scores_bit_identical_to_dequantized_dense() {
        // The tentpole invariant end to end: scoring through fused packed
        // matmuls == scoring through dense matmuls over the dequantized
        // weights, exactly (same accumulation order everywhere).
        let tier = tiny_tier(vec![]);
        let layout = PlanLayout::monolithic(&tier);
        let ckpt = checkpoint(3, &tier);
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let packed = build_native(&tier, &layout, &ckpt, &spec, true);
        let dense = build_native(&tier, &layout, &ckpt, &spec, false);
        let rows = score_input(5, 7); // crosses the batch_eval=4 chunk edge
        let a = packed.score_rows(&rows).unwrap();
        let b = dense.score_rows(&rows).unwrap();
        assert_eq!(a, b);
        assert!(a.iter().all(|(nll, _)| nll.is_finite() && *nll >= 0.0), "{a:?}");
        assert!(a.iter().map(|(nll, _)| nll).sum::<f64>() > 0.0, "nothing scored: {a:?}");
    }

    #[test]
    fn encoded_scores_bit_identical_to_packed() {
        // Entropy-coded residency is lossless by construction: the
        // streamed Huffman decode feeds the same axpy accumulation order
        // as the packed fused path, so scores agree to the bit — and the
        // thread setting is irrelevant to the (sequential) encoded path.
        let tier = tiny_tier(vec![]);
        let layout = PlanLayout::monolithic(&tier);
        let ckpt = checkpoint(37, &tier);
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let packed = build_native(&tier, &layout, &ckpt, &spec, true);
        let params: Vec<NativeParam> = layout
            .params
            .iter()
            .map(|pp| {
                let (_, data) = ckpt.iter().find(|(n, _)| n == &pp.source).unwrap();
                let per: usize = pp.shape.iter().skip(1).product::<usize>().max(1);
                let slice = match pp.layers {
                    Some((lo, hi)) => &data[lo * per..hi * per],
                    None => &data[..],
                };
                if tier.quantized_params.iter().any(|q| q == &pp.source) {
                    let pk = PackedParam::quantize_slice(&pp.shape, slice, &spec).unwrap();
                    NativeParam::Encoded(crate::quant::entropy::encode_param(&pk).unwrap())
                } else {
                    NativeParam::Dense(slice.to_vec())
                }
            })
            .collect();
        let mut enc = NativeModel::build(&tier, &layout, params).unwrap();
        let rows = score_input(41, 6);
        let want = packed.score_rows(&rows).unwrap();
        assert_eq!(enc.score_rows(&rows).unwrap(), want);
        enc.set_threads(4);
        assert_eq!(enc.score_rows(&rows).unwrap(), want, "threads must not affect decode");
    }

    #[test]
    fn thread_counts_score_bit_identically() {
        // Column-parallel scoring is a pure partitioning of the output
        // space: 1-, 2-, and 4-thread runs must agree to the bit.
        let tier = tiny_tier(vec![]);
        let layout = PlanLayout::monolithic(&tier);
        let ckpt = checkpoint(23, &tier);
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let mut m = build_native(&tier, &layout, &ckpt, &spec, true);
        let rows = score_input(29, 6);
        m.set_threads(1);
        assert_eq!(m.threads(), 1);
        let base = m.score_rows(&rows).unwrap();
        for t in [2usize, 4] {
            m.set_threads(t);
            assert_eq!(m.score_rows(&rows).unwrap(), base, "{t} threads diverged");
        }
    }

    #[test]
    fn staged_layout_scores_match_monolithic() {
        // A pipeline-sharded layout reassembles to the same native model:
        // per-layer slice quantization makes the weights — and therefore
        // the fused scores — bit-identical across plan shapes.
        let tier_m = tiny_tier(vec![]);
        let tier_s = tiny_tier(two_stages());
        let mono = PlanLayout::monolithic(&tier_m);
        let staged = PlanLayout::staged(&tier_s).unwrap();
        let ckpt = checkpoint(11, &tier_m);
        let spec = QuantSpec::new(DataType::Int, 3, Some(16));
        let a = build_native(&tier_m, &mono, &ckpt, &spec, true);
        let b = build_native(&tier_s, &staged, &ckpt, &spec, true);
        let rows = score_input(13, 5);
        assert_eq!(a.score_rows(&rows).unwrap(), b.score_rows(&rows).unwrap());
    }

    #[test]
    fn build_and_score_validate_inputs() {
        let tier = tiny_tier(vec![]);
        let layout = PlanLayout::monolithic(&tier);
        let ckpt = checkpoint(17, &tier);
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let m = build_native(&tier, &layout, &ckpt, &spec, true);
        // Short rows and out-of-vocab tokens are errors, not panics.
        assert!(m.score_rows(&[(vec![0; S - 1], vec![0.0; S - 1])]).is_err());
        let mut toks = vec![0i32; S];
        toks[3] = V as i32;
        assert!(m.score_rows(&[(toks, vec![1.0; S])]).is_err());
        // Param-count mismatch at build time.
        assert!(NativeModel::build(&tier, &layout, Vec::new()).is_err());
    }

    #[test]
    fn all_masked_rows_score_zero() {
        let tier = tiny_tier(vec![]);
        let layout = PlanLayout::monolithic(&tier);
        let ckpt = checkpoint(19, &tier);
        let spec = QuantSpec::new(DataType::Fp, 4, Some(16));
        let m = build_native(&tier, &layout, &ckpt, &spec, true);
        let scored = m.score_rows(&[(vec![1i32; S], vec![0.0; S])]).unwrap();
        assert_eq!(scored, vec![(0.0, 0.0)]);
    }
}
