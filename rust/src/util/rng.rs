//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component of the study (corpus, init, data order, task
//! sampling, property tests) draws from [`Rng`], a xoshiro256++ generator
//! seeded through SplitMix64. Determinism is a hard requirement: the sweep
//! coordinator caches results keyed by config hash, and re-running a cell
//! must reproduce it bit-for-bit.

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; fast and with
/// excellent statistical quality for simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (e.g. one per tensor / worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift reduction
    /// (bias negligible for the ranges used here).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (both values used across calls).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= 0.0 { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a slice with i.i.d. `N(0, std^2)` float32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Sample an index from unnormalized weights (linear scan; used for
    /// small categorical draws like topic selection).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Precomputed Zipf(α) sampler over `{0, .., n-1}` using inverse-CDF binary
/// search. The synthetic corpus unigram distribution (DESIGN.md §1) uses
/// α ≈ 1.1, matching natural-language token frequency profiles.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn prob(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            // Each bucket within 10% of expectation.
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(100, 1.1);
        for i in 1..100 {
            assert!(z.prob(i) <= z.prob(i - 1) + 1e-12);
        }
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(100);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
