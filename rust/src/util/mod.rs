//! From-scratch infrastructure substrates.
//!
//! The build image vendors only the crates the `xla` FFI needs, so the
//! pieces a production framework would normally pull from crates.io are
//! implemented here instead (and unit-tested like any other module):
//!
//! * [`json`] — a strict JSON parser/serializer (manifest, results store,
//!   golden vectors).
//! * [`rng`] — a deterministic xoshiro256++ PRNG with normal/Zipf sampling;
//!   every experiment is seeded and replayable.
//! * [`pool`] — a fixed-size scoped thread pool used by the sweep
//!   coordinator and the quantization hot path.
//! * [`argparse`] — a small declarative CLI argument parser.
//! * [`proptest`] — a minimal property-based testing harness (seeded case
//!   generation + shrinking-free failure reporting) used across the quant
//!   and coordinator invariants.
//! * [`progress`] — wall-clock scoped timers and rate reporting.
//! * [`order`] — NaN-safe total orderings for score argmax/sorting.

pub mod argparse;
pub mod json;
pub mod order;
pub mod pool;
pub mod progress;
pub mod proptest;
pub mod rng;
pub mod toml;

/// FNV-1a offset basis: the seed for [`fnv1a_fold`] chains.
pub const FNV1A_OFFSET: u64 = 0xcbf29ce484222325;

/// Fold `bytes` into a running FNV-1a state — the streaming form of
/// [`fnv1a`], used where a key is hashed from multiple components
/// without assembling a byte buffer (the server's score-cache row keys).
pub fn fnv1a_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Simple stable 64-bit FNV-1a hash, used for config-keyed caching in the
/// results store (stable across runs and platforms, unlike `DefaultHasher`).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_fold(FNV1A_OFFSET, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_stable_known_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        // Distinct inputs hash apart.
        assert_ne!(fnv1a(b"int:4:64"), fnv1a(b"fp:4:64"));
    }
}
