//! Fixed-size scoped worker pool.
//!
//! The sweep coordinator fans quantize+eval cells across workers and the
//! quant hot path parallelizes across tensors. With no tokio/rayon in the
//! vendored crate set, this is a small work-stealing-free pool built on
//! `std::thread::scope` + a locked deque: tasks are coarse (milliseconds to
//! seconds), so a single contended queue is nowhere near the bottleneck.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Run `f(i)` for every `i in 0..n` across up to `threads` workers and
/// collect results in index order. Panics in tasks propagate.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_init(n, threads, || (), |_, i| f(i))
}

/// [`parallel_map`] with per-worker scratch state: each worker calls
/// `init()` exactly once when it starts and threads the value through every
/// task it claims — the column-parallel fused matmul uses this for its
/// decode panel so workers never share (or reallocate per task) a scratch
/// buffer. Results are collected in index order; panics in tasks propagate.
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&mut state, i);
                    **slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    drop(slots);
    out.into_iter().map(|x| x.expect("worker dropped a slot")).collect()
}

/// Default worker count: physical parallelism, capped to keep the PJRT CPU
/// backend (itself multithreaded) from oversubscription.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 16)
}

/// Worker count for column-parallel fused scoring: the `KBITSCALE_THREADS`
/// environment override when it parses to `>= 1` (clamped to 64), else
/// [`default_threads`]. Latched once per process — like
/// `KBITSCALE_FORCE_SCALAR`, set it before the first fused model is built.
pub fn scoring_threads() -> usize {
    static ACTIVE: OnceLock<usize> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let env = std::env::var("KBITSCALE_THREADS").ok();
        match env.and_then(|v| v.trim().parse::<usize>().ok()) {
            Some(t) if t >= 1 => t.min(64),
            _ => default_threads(),
        }
    })
}

/// A bounded MPMC channel used by the coordinator for work distribution
/// with backpressure (producers block when `cap` items are queued).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct QueueState<T> {
    items: std::collections::VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(QueueState { items: std::collections::VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; returns `false` if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        while st.items.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; returns `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(x) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Blocking pop with a deadline: returns `None` once `dur` elapses
    /// with nothing available, or once the queue is closed and drained.
    /// The server's micro-batch dispatcher uses this for its
    /// latency-bound flush window.
    pub fn pop_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(x) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            st = self.not_empty.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    /// Close the queue: producers fail, consumers drain the remainder.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A one-shot cross-thread shutdown signal: waiters sleep on a condvar
/// (no polling wakeups) and are released the moment the latch trips.
/// The fleet router's background prober sleeps on this between probe
/// rounds so serving shutdown never waits out a sleep slice.
pub struct Latch {
    state: Mutex<bool>,
    cv: Condvar,
}

impl Latch {
    pub fn new() -> Latch {
        Latch { state: Mutex::new(false), cv: Condvar::new() }
    }

    /// Trip the latch, waking every current and future waiter.
    pub fn set(&self) {
        *self.state.lock().unwrap() = true;
        self.cv.notify_all();
    }

    pub fn is_set(&self) -> bool {
        *self.state.lock().unwrap()
    }

    /// Sleep up to `dur`; returns `true` (immediately) once the latch is
    /// tripped, `false` when the full duration elapsed untripped.
    pub fn wait_timeout(&self, dur: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        loop {
            if *st {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }
}

impl Default for Latch {
    fn default() -> Self {
        Latch::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_runs_every_task_once() {
        let count = AtomicU64::new(0);
        let _ = parallel_map(1000, 8, |_| count.fetch_add(1, Ordering::Relaxed));
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_map_init_runs_init_once_per_worker() {
        let inits = AtomicU64::new(0);
        let got = parallel_map_init(
            64,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::new()
            },
            |scratch, i| {
                scratch.push(i); // per-worker state survives across tasks
                (i, scratch.len())
            },
        );
        // One init per spawned worker, never one per task.
        let n_inits = inits.load(Ordering::Relaxed);
        assert!(n_inits <= 4, "{n_inits} inits for 4 workers");
        assert!(n_inits >= 1);
        // Every task ran, in index order, and scratch lengths show reuse:
        // the per-worker task counts sum to the task total.
        let ids: Vec<usize> = got.iter().map(|&(i, _)| i).collect();
        assert_eq!(ids, (0..64).collect::<Vec<_>>());
        let max_len = got.iter().map(|&(_, l)| l).max().unwrap_or(0);
        assert!(max_len * n_inits as usize >= 64, "scratch not reused across tasks");
    }

    #[test]
    fn parallel_map_init_serial_path_shares_one_state() {
        let got = parallel_map_init(5, 1, || 0usize, |acc, i| {
            *acc += i;
            *acc
        });
        assert_eq!(got, vec![0, 1, 3, 6, 10]);
    }

    #[test]
    fn scoring_threads_is_latched_and_positive() {
        let a = scoring_threads();
        assert!(a >= 1);
        assert_eq!(a, scoring_threads(), "latched value must be stable");
    }

    #[test]
    fn bounded_queue_roundtrip() {
        let q = BoundedQueue::new(4);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..100 {
                    assert!(q.push(i));
                }
                q.close();
            });
            let mut got = Vec::new();
            while let Some(x) = q.pop() {
                got.push(x);
            }
            assert_eq!(got, (0..100).collect::<Vec<_>>());
        });
    }

    #[test]
    fn closed_queue_rejects_push() {
        let q = BoundedQueue::new(2);
        q.close();
        assert!(!q.push(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_timeout_times_out_and_delivers() {
        use std::time::Duration;
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(30)), None);
        assert!(t.elapsed() >= Duration::from_millis(25));
        assert!(q.push(7));
        assert_eq!(q.pop_timeout(Duration::from_millis(30)), Some(7));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(30)), None);
    }

    #[test]
    fn latch_times_out_untripped_and_releases_on_set() {
        use std::time::{Duration, Instant};
        let l = Latch::new();
        assert!(!l.is_set());
        let t = Instant::now();
        assert!(!l.wait_timeout(Duration::from_millis(30)), "untripped latch must time out");
        assert!(t.elapsed() >= Duration::from_millis(25));
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(20));
                l.set();
            });
            let t = Instant::now();
            assert!(
                l.wait_timeout(Duration::from_secs(10)),
                "set() must release the waiter early"
            );
            assert!(t.elapsed() < Duration::from_secs(5), "waiter released long before timeout");
        });
        assert!(l.is_set());
        assert!(l.wait_timeout(Duration::from_millis(1)), "tripped latch returns immediately");
    }

    #[test]
    fn queue_applies_backpressure() {
        let q = BoundedQueue::new(2);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.len(), 2);
        // Third push would block; drain one first from another thread.
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                assert_eq!(q.pop(), Some(1));
            });
            assert!(q.push(3)); // unblocks once the consumer pops
        });
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }
}
