//! Tiny declarative CLI argument parser (clap is not in the vendored set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors, defaults, and generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Declarative option spec for one subcommand.
pub struct ArgSpec {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptDef>,
}

struct OptDef {
    key: &'static str,
    help: &'static str,
    default: Option<&'static str>,
    is_flag: bool,
    is_multi: bool,
}

/// Parsed arguments.
pub struct Args {
    values: BTreeMap<String, String>,
    multi: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        ArgSpec { name, about, opts: Vec::new() }
    }

    /// `--key <value>` option with an optional default.
    pub fn opt(mut self, key: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptDef { key, help, default, is_flag: false, is_multi: false });
        self
    }

    /// Repeatable `--key <value>` option: every occurrence is kept, in
    /// order (`kbitscale fleet --worker a:1 --worker b:2`).
    pub fn multi(mut self, key: &'static str, help: &'static str) -> Self {
        self.opts.push(OptDef { key, help, default: None, is_flag: false, is_multi: true });
        self
    }

    /// Boolean `--key` flag.
    pub fn flag(mut self, key: &'static str, help: &'static str) -> Self {
        self.opts.push(OptDef { key, help, default: None, is_flag: true, is_multi: false });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                ""
            } else if o.is_multi {
                " <value> (repeatable)"
            } else {
                " <value>"
            };
            let dft = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{}\n      {}{}\n", o.key, kind, o.help, dft));
        }
        s
    }

    /// Parse a raw argument list (not including the program/subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut multi: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.key.to_string(), d.to_string());
            }
        }
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let def = self
                    .opts
                    .iter()
                    .find(|o| o.key == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n\n{}", self.usage()))?;
                if def.is_flag {
                    if inline.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.push(key.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} requires a value"))?
                            .clone(),
                    };
                    if def.is_multi {
                        multi.entry(key.to_string()).or_default().push(v);
                    } else {
                        values.insert(key.to_string(), v);
                    }
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { values, multi, flags, positional })
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Result<&str> {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("missing required option --{key}"))
    }

    pub fn opt_get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("--{key} must be an integer"))
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.parse().with_context(|| format!("--{key} must be a number"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Every occurrence of a repeatable option, in command-line order
    /// (empty when never given).
    pub fn occurrences(&self, key: &str) -> Vec<String> {
        self.multi.get(key).cloned().unwrap_or_default()
    }

    /// Comma-separated list helper: `--tiers t0,t1,t2`.
    pub fn list(&self, key: &str) -> Result<Vec<String>> {
        Ok(self
            .get(key)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect())
    }

    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.list(key)?
            .iter()
            .map(|s| s.parse::<usize>().with_context(|| format!("--{key}: bad integer {s:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "test command")
            .opt("bits", Some("4"), "precision")
            .opt("dtype", None, "data type")
            .flag("verbose", "chatty")
    }

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec().parse(&raw(&[])).unwrap();
        assert_eq!(a.usize("bits").unwrap(), 4);
        let a = spec().parse(&raw(&["--bits", "8"])).unwrap();
        assert_eq!(a.usize("bits").unwrap(), 8);
        let a = spec().parse(&raw(&["--bits=3"])).unwrap();
        assert_eq!(a.usize("bits").unwrap(), 3);
    }

    #[test]
    fn flags_and_positionals() {
        let a = spec().parse(&raw(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert!(!spec().parse(&raw(&[])).unwrap().flag("verbose"));
    }

    #[test]
    fn errors() {
        assert!(spec().parse(&raw(&["--nope"])).is_err());
        assert!(spec().parse(&raw(&["--bits"])).is_err());
        assert!(spec().parse(&raw(&["--verbose=1"])).is_err());
        let a = spec().parse(&raw(&[])).unwrap();
        assert!(a.get("dtype").is_err()); // required, no default
    }

    #[test]
    fn multi_options_keep_every_occurrence_in_order() {
        let s = ArgSpec::new("t", "t").multi("worker", "worker address");
        let a = s.parse(&raw(&["--worker", "a:1", "--worker=b:2", "--worker", "c:3"])).unwrap();
        assert_eq!(a.occurrences("worker"), vec!["a:1", "b:2", "c:3"]);
        let s = ArgSpec::new("t", "t").multi("worker", "worker address");
        assert!(s.parse(&raw(&[])).unwrap().occurrences("worker").is_empty());
        let s = ArgSpec::new("t", "t").multi("worker", "worker address");
        assert!(s.parse(&raw(&["--worker"])).is_err(), "a multi option still needs a value");
    }

    #[test]
    fn lists() {
        let s = ArgSpec::new("t", "t").opt("tiers", Some("t0,t1"), "");
        let a = s.parse(&raw(&[])).unwrap();
        assert_eq!(a.list("tiers").unwrap(), vec!["t0", "t1"]);
        let s2 = ArgSpec::new("t", "t").opt("ks", Some("3,4,8"), "");
        assert_eq!(s2.parse(&raw(&[])).unwrap().usize_list("ks").unwrap(), vec![3, 4, 8]);
    }
}
