//! Scoped wall-clock timers and lightweight run metrics.
//!
//! The coordinator reports cell throughput and per-phase timings through
//! these helpers; the perf pass (EXPERIMENTS.md §Perf) reads the same
//! numbers, so measurement code is shared between production and benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Wall-clock timer with split reporting.
pub struct Timer {
    start: Instant,
    label: String,
}

impl Timer {
    pub fn start(label: impl Into<String>) -> Self {
        Timer { start: Instant::now(), label: label.into() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Log the elapsed time (info level) and return it.
    pub fn finish(self) -> f64 {
        let dt = self.elapsed_s();
        log::info!("{}: {:.3}s", self.label, dt);
        dt
    }
}

/// Measure the best-of-`reps` wall time of `f` (after `warmup` calls), the
/// convention all `benches/` targets use for latency numbers.
pub fn bench_best<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Shared atomic counters for coarse run accounting (cells done, PJRT
/// executions, bytes quantized). Cheap enough to leave on everywhere.
#[derive(Default)]
pub struct Counters {
    pub cells: AtomicU64,
    pub executions: AtomicU64,
    pub bytes_quantized: AtomicU64,
}

impl Counters {
    pub fn bump_cells(&self) {
        self.cells.fetch_add(1, Ordering::Relaxed);
    }
    pub fn bump_exec(&self) {
        self.executions.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_bytes(&self, n: u64) {
        self.bytes_quantized.fetch_add(n, Ordering::Relaxed);
    }
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.cells.load(Ordering::Relaxed),
            self.executions.load(Ordering::Relaxed),
            self.bytes_quantized.load(Ordering::Relaxed),
        )
    }
}

/// Simple stderr logger (the `log` facade has no backend in the vendored
/// set). Level comes from `KBITSCALE_LOG` (error|warn|info|debug|trace).
pub fn init_logging() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("KBITSCALE_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            _ => log::LevelFilter::Info,
        };
        let _ = log::set_boxed_logger(Box::new(StderrLogger));
        log::set_max_level(level);
    });
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }
    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:5}] {}", record.level(), record.args());
        }
    }
    fn flush(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_elapsed() {
        let t = Timer::start("test");
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(t.elapsed_s() >= 0.009);
    }

    #[test]
    fn bench_best_returns_minimum() {
        let dt = bench_best(1, 3, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(dt >= 0.001 && dt < 0.5);
    }

    #[test]
    fn counters_accumulate() {
        let c = Counters::default();
        c.bump_cells();
        c.bump_cells();
        c.bump_exec();
        c.add_bytes(128);
        assert_eq!(c.snapshot(), (2, 1, 128));
    }
}
