//! Total orderings for floating-point scores.
//!
//! `partial_cmp(..).unwrap()` over model scores panics the worker thread
//! the moment an executable returns a NaN NLL. Score selection in the
//! serving layer (`choose`) and the zero-shot harness uses this NaN-last
//! total order instead: a NaN can never win an argmax, and callers detect
//! the all-NaN case by checking the winner — surfacing an error response
//! rather than unwinding a thread.

use std::cmp::Ordering;

/// Total order on `f64` in which every NaN sorts **below** every non-NaN
/// value (NaNs compare equal to each other). A `max_by` using this
/// comparator selects a NaN only when every candidate is NaN.
pub fn nan_last_cmp(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => a.partial_cmp(&b).expect("non-NaN floats compare"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_reals_normally() {
        assert_eq!(nan_last_cmp(1.0, 2.0), Ordering::Less);
        assert_eq!(nan_last_cmp(2.0, 1.0), Ordering::Greater);
        assert_eq!(nan_last_cmp(1.5, 1.5), Ordering::Equal);
        assert_eq!(nan_last_cmp(f64::NEG_INFINITY, -1e308), Ordering::Less);
    }

    #[test]
    fn nan_loses_to_everything() {
        assert_eq!(nan_last_cmp(f64::NAN, f64::NEG_INFINITY), Ordering::Less);
        assert_eq!(nan_last_cmp(f64::NEG_INFINITY, f64::NAN), Ordering::Greater);
        assert_eq!(nan_last_cmp(f64::NAN, f64::NAN), Ordering::Equal);
    }

    #[test]
    fn max_by_never_picks_nan_over_a_real() {
        let scores = [f64::NAN, -3.0, f64::NAN, -1.0, -2.0];
        let best = (0..scores.len())
            .max_by(|&a, &b| nan_last_cmp(scores[a], scores[b]))
            .unwrap();
        assert_eq!(best, 3);
        // All-NaN: an index still comes back (no panic); the caller
        // checks the winning score and surfaces an error.
        let all_nan = [f64::NAN, f64::NAN];
        let best = (0..2).max_by(|&a, &b| nan_last_cmp(all_nan[a], all_nan[b])).unwrap();
        assert!(all_nan[best].is_nan());
    }
}
