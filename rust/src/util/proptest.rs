//! Minimal property-based testing harness.
//!
//! The vendored crate set has no `proptest`, so invariants are checked with
//! this seeded-case generator instead: run a property over `n` random cases
//! drawn from explicit generators; on failure, report the case index and
//! seed so the exact input reproduces deterministically. (No shrinking —
//! generators here produce small cases by construction.)

use crate::util::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` seeded cases; panic with a
/// reproducible seed on the first failure message returned.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xABCD_0000 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generators for common test inputs.
pub mod gen {
    use crate::util::rng::Rng;

    /// A random tensor-ish f32 vector: mixed scales, occasional outliers,
    /// zeros and exact-negatives — the shapes quantizers must survive.
    pub fn weights(rng: &mut Rng, max_len: usize) -> Vec<f32> {
        let n = 1 + rng.below(max_len);
        let std = 10f64.powf(rng.range_f64(-3.0, 1.0));
        (0..n)
            .map(|_| {
                let roll = rng.f64();
                if roll < 0.02 {
                    0.0
                } else if roll < 0.05 {
                    // outlier, ~20x the bulk std (paper §3)
                    (rng.normal() * std * 20.0) as f32
                } else {
                    (rng.normal() * std) as f32
                }
            })
            .collect()
    }

    /// Random quantization block size from the paper's sweep range.
    pub fn block(rng: &mut Rng) -> usize {
        [16, 32, 64, 128, 256, 512, 1024][rng.below(7)]
    }

    /// Random bit width 2..=8.
    pub fn bits(rng: &mut Rng) -> usize {
        2 + rng.below(7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("tautology", 50, |rng, _| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn check_reports_failures() {
        check("always-fails", 5, |_, _| Err("always-fails".into()));
    }

    #[test]
    fn weight_gen_produces_varied_cases() {
        let mut rng = crate::util::rng::Rng::new(1);
        let mut saw_zero = false;
        let mut saw_large = false;
        for _ in 0..100 {
            let w = gen::weights(&mut rng, 256);
            assert!(!w.is_empty() && w.len() <= 256);
            saw_zero |= w.iter().any(|&x| x == 0.0);
            saw_large |= w.iter().any(|&x| x.abs() > 1.0);
        }
        assert!(saw_zero && saw_large);
    }
}
