//! Minimal TOML-subset parser for run configuration files.
//!
//! Supports the subset the repo's configs use: `[section]` headers,
//! `key = value` with string / integer / float / bool / array-of-scalar
//! values, `#` comments, and bare keys. Produces the same [`Json`] value
//! tree as the JSON parser so downstream code has one access API.

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Parse TOML-subset text into a nested [`Json::Obj`]:
/// top-level keys plus one object per `[section]`.
pub fn parse(text: &str) -> Result<Json> {
    let mut root = std::collections::BTreeMap::new();
    let mut section: Option<String> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let ctx = || format!("line {}: {raw:?}", lineno + 1);
        if let Some(name) = line.strip_prefix('[') {
            let name = name.strip_suffix(']').with_context(ctx)?.trim();
            if name.is_empty() {
                bail!("empty section name at {}", ctx());
            }
            root.entry(name.to_string())
                .or_insert_with(|| Json::Obj(Default::default()));
            section = Some(name.to_string());
            continue;
        }
        let (key, value) = line.split_once('=').with_context(ctx)?;
        let key = key.trim().to_string();
        let value = parse_value(value.trim()).with_context(ctx)?;
        match &section {
            None => {
                root.insert(key, value);
            }
            Some(s) => {
                let Json::Obj(m) = root.get_mut(s).unwrap() else {
                    unreachable!()
                };
                m.insert(key, value);
            }
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a double-quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Json> {
    if s.is_empty() {
        bail!("missing value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(Json::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        return Ok(Json::Arr(
            split_top_level(inner)
                .iter()
                .map(|p| parse_value(p.trim()))
                .collect::<Result<_>>()?,
        ));
    }
    s.parse::<f64>()
        .map(Json::Num)
        .with_context(|| format!("unparseable value {s:?}"))
}

/// Split on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let t = parse(
            r#"
# run config
name = "fig2"          # inline comment
threads = 4
[sweep]
ks = [3, 4, 8, 16]
families = ["optlike", "gpt2like"]
zero_shot = true
lr = 3e-3
"#,
        )
        .unwrap();
        assert_eq!(t.get("name").unwrap().as_str().unwrap(), "fig2");
        assert_eq!(t.get("threads").unwrap().as_usize().unwrap(), 4);
        let sweep = t.get("sweep").unwrap();
        assert_eq!(sweep.get("ks").unwrap().usizes().unwrap(), vec![3, 4, 8, 16]);
        assert_eq!(
            sweep.get("families").unwrap().as_arr().unwrap()[1].as_str().unwrap(),
            "gpt2like"
        );
        assert!(sweep.get("zero_shot").unwrap().as_bool().unwrap());
        assert!((sweep.get("lr").unwrap().as_f64().unwrap() - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let t = parse("tag = \"a#b\"").unwrap();
        assert_eq!(t.get("tag").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("bare line").is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn empty_and_comment_only_ok() {
        let t = parse("# nothing\n\n").unwrap();
        assert!(t.as_obj().unwrap().is_empty());
    }
}
