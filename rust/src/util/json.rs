//! Minimal strict JSON parser and serializer.
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms the
//! repo never produces. Used for `artifacts/manifest.json`,
//! `artifacts/codebooks.json`, the JSONL results store, and figure CSV/JSON
//! sidecars. Numbers are held as `f64` (all values in this repo fit).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable config hashing depends on this).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {}", self.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {}", self.kind())),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {}", self.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {}", self.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {}", self.kind())),
        }
    }

    /// Field lookup with a contextual error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing field {key:?}"))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn f64s(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn f32s(&self) -> Result<Vec<f32>> {
        Ok(self.f64s()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn usizes(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    // -- serialization ---------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; the results store encodes them as huge
        // sentinels the analysis layer clamps anyway (paper clamps
        // unstable perplexities to 100 the same way).
        out.push_str(if n.is_nan() {
            "null"
        } else if n > 0.0 {
            "1e308"
        } else {
            "-1e308"
        });
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Shortest round-trip float formatting.
        let _ = write!(out, "{n}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs are not produced by this repo;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        e => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string at byte {}", self.i),
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = self
                            .b
                            .get(start..start + width)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
        assert_eq!(*v.get("c").unwrap(), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn float_roundtrip_is_exact() {
        let vals = [0.1f64, -1.0 / 3.0, 1e-40, 123456789.123456, f64::MIN_POSITIVE];
        let j = Json::arr_f64(&vals);
        let back = Json::parse(&j.dump()).unwrap().f64s().unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        let esc = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(esc.as_str().unwrap(), "Aé");
    }

    #[test]
    fn object_key_order_is_deterministic() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap();
        assert_eq!(a.dump(), b.dump());
    }

    #[test]
    fn nonfinite_nums_serialize_to_sentinels() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "1e308");
    }
}
