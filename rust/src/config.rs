//! Run configuration files: a declarative alternative to CLI flags for
//! training and sweep campaigns (`kbitscale sweep --config run.toml`).
//!
//! ```toml
//! # configs/headline.toml
//! [train]
//! families = ["optlike", "pythialike", "gpt2like", "bloomlike"]
//! tiers    = ["t0", "t1", "t2", "t3"]
//! steps    = 500
//! base_lr  = 3e-3
//!
//! [sweep]
//! grid      = "headline"
//! ks        = [3, 4, 8, 16]
//! threads   = 2
//! zero_shot = true
//!
//! [eval]
//! ppl_sequences = 48
//! zs_examples   = 48
//! ```
//!
//! Missing sections/keys fall back to the same defaults the CLI uses, so
//! a config file only needs to state what it changes.

use std::path::Path;

use anyhow::{Context, Result};

use crate::eval::EvalConfig;
use crate::train::TrainConfig;
use crate::util::json::Json;
use crate::util::toml;

/// Parsed run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub families: Vec<String>,
    pub tiers: Vec<String>,
    pub train: TrainConfig,
    pub grid: String,
    pub ks: Vec<usize>,
    pub threads: usize,
    pub eval: EvalConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            families: vec![
                "optlike".into(),
                "pythialike".into(),
                "gpt2like".into(),
                "bloomlike".into(),
            ],
            tiers: vec!["t0".into(), "t1".into(), "t2".into(), "t3".into()],
            train: TrainConfig::default(),
            grid: "headline".into(),
            ks: vec![3, 4, 8, 16],
            threads: 2,
            eval: EvalConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = toml::parse(text)?;
        let mut cfg = RunConfig::default();

        if let Some(train) = doc.opt("train") {
            if let Some(v) = train.opt("families") {
                cfg.families = strings(v)?;
            }
            if let Some(v) = train.opt("tiers") {
                cfg.tiers = strings(v)?;
            }
            if let Some(v) = train.opt("steps") {
                cfg.train.steps = v.as_usize()?;
            }
            if let Some(v) = train.opt("base_lr") {
                cfg.train.base_lr = v.as_f64()?;
            }
            if let Some(v) = train.opt("warmup_steps") {
                cfg.train.warmup_steps = v.as_usize()?;
            }
        }
        if let Some(sweep) = doc.opt("sweep") {
            if let Some(v) = sweep.opt("grid") {
                cfg.grid = v.as_str()?.to_string();
            }
            if let Some(v) = sweep.opt("ks") {
                cfg.ks = v.usizes()?;
            }
            if let Some(v) = sweep.opt("threads") {
                cfg.threads = v.as_usize()?;
            }
        }
        if let Some(eval) = doc.opt("eval") {
            if let Some(v) = eval.opt("ppl_sequences") {
                cfg.eval.ppl_sequences = v.as_usize()?;
            }
            if let Some(v) = eval.opt("zs_examples") {
                cfg.eval.zs_examples = v.as_usize()?;
            }
        }
        Ok(cfg)
    }
}

fn strings(v: &Json) -> Result<Vec<String>> {
    v.as_arr()?
        .iter()
        .map(|x| Ok(x.as_str()?.to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let c = RunConfig::from_toml("").unwrap();
        assert_eq!(c.grid, "headline");
        assert_eq!(c.train.steps, TrainConfig::default().steps);
        assert_eq!(c.families.len(), 4);
    }

    #[test]
    fn overrides_apply() {
        let c = RunConfig::from_toml(
            r#"
[train]
families = ["gpt2like"]
steps = 42
base_lr = 1e-4
[sweep]
grid = "datatypes"
ks = [4]
threads = 8
[eval]
ppl_sequences = 16
"#,
        )
        .unwrap();
        assert_eq!(c.families, vec!["gpt2like"]);
        assert_eq!(c.train.steps, 42);
        assert!((c.train.base_lr - 1e-4).abs() < 1e-15);
        assert_eq!(c.grid, "datatypes");
        assert_eq!(c.ks, vec![4]);
        assert_eq!(c.threads, 8);
        assert_eq!(c.eval.ppl_sequences, 16);
        // Unspecified keys keep defaults.
        assert_eq!(c.eval.zs_examples, EvalConfig::default().zs_examples);
    }

    #[test]
    fn bad_types_error() {
        assert!(RunConfig::from_toml("[train]\nsteps = \"many\"").is_err());
    }
}
