//! Synthetic zero-shot tasks mirroring the paper's evaluation suite.
//!
//! Four generators with the metric structure of the paper's tasks
//! (Section 4): every example is a context plus `n` candidate
//! continuations, scored by length-normalized log-likelihood exactly like
//! the EleutherAI harness scores multiple-choice tasks. Random baselines:
//! lambada-like 1/4, piqa-like 1/2, hellaswag-like 1/4, winogrande-like
//! 1/2 → mean 0.375, close to the paper's ~35% "random" floor.

use crate::util::rng::Rng;

use super::corpus::{Corpus, Generator, TRIGGER};
use super::BOS;

/// One multiple-choice example: shared context, candidate continuations,
/// index of the correct one.
#[derive(Debug, Clone)]
pub struct Example {
    pub context: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
}

/// The four tasks of the suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// Long-range last-token prediction (LAMBADA-like): the planted
    /// trigger→payload pair determines the final token; 4 single-token
    /// choices.
    Lambada,
    /// 2-way multi-token continuation (PiQA-like): true-topic continuation
    /// vs other-topic continuation.
    Piqa,
    /// 4-way longer continuation (HellaSwag-like).
    Hellaswag,
    /// 2-way single-token successor choice (Winogrande-like).
    Winogrande,
}

impl Task {
    pub const ALL: [Task; 4] = [Task::Lambada, Task::Piqa, Task::Hellaswag, Task::Winogrande];

    pub fn name(self) -> &'static str {
        match self {
            Task::Lambada => "lambada",
            Task::Piqa => "piqa",
            Task::Hellaswag => "hellaswag",
            Task::Winogrande => "winogrande",
        }
    }

    pub fn n_choices(self) -> usize {
        match self {
            Task::Lambada | Task::Hellaswag => 4,
            Task::Piqa | Task::Winogrande => 2,
        }
    }

    pub fn random_baseline(self) -> f64 {
        1.0 / self.n_choices() as f64
    }
}

/// Mean random baseline across the suite (paper: ~35%).
pub fn suite_random_baseline() -> f64 {
    Task::ALL.iter().map(|t| t.random_baseline()).sum::<f64>() / Task::ALL.len() as f64
}

/// Deterministic task-set generator over a corpus.
pub struct TaskSet {
    corpus_seed: u64,
}

impl TaskSet {
    pub fn new(corpus: &Corpus) -> Self {
        TaskSet { corpus_seed: corpus.cfg.seed }
    }

    /// Generate `n` examples of `task`. Deterministic per (task, corpus).
    pub fn examples(&self, gen: &Generator, task: Task, n: usize) -> Vec<Example> {
        let mut rng = Rng::new(self.corpus_seed ^ 0x7A5C ^ (task as u64) << 32);
        (0..n).map(|_| self.example(gen, task, &mut rng)).collect()
    }

    fn example(&self, gen: &Generator, task: Task, rng: &mut Rng) -> Example {
        match task {
            Task::Lambada => self.lambada(gen, rng),
            Task::Piqa => self.choice_continuation(gen, rng, 2, 6),
            Task::Hellaswag => self.choice_continuation(gen, rng, 4, 10),
            Task::Winogrande => self.winogrande(gen, rng),
        }
    }

    /// Context = sequence truncated before its planted final token;
    /// choices = the true completion + 3 distractors (images of the payload
    /// under other topics, falling back to random content tokens).
    fn lambada(&self, gen: &Generator, rng: &mut Rng) -> Example {
        loop {
            let (toks, topic) = gen.sequence(rng);
            let Some(tpos) = toks.iter().position(|&t| t == TRIGGER) else {
                continue;
            };
            if tpos + 1 >= toks.len() - 1 {
                continue;
            }
            let context = toks[..toks.len() - 1].to_vec();
            let correct = *toks.last().unwrap();
            let payload = toks[tpos + 1];
            let mut choices = vec![vec![correct]];
            let mut used = vec![correct];
            let mut alt_topic = 0usize;
            while choices.len() < 4 {
                // Distractors: same payload through a different topic map,
                // so they are plausible under the corpus marginal.
                let cand = if alt_topic < 8 {
                    let t = (topic + 1 + alt_topic) % 8;
                    alt_topic += 1;
                    let rel = (payload - super::CONTENT_BASE - 1).max(0) as usize;
                    super::CONTENT_BASE + 1 + gen.successor(t, rel) as i32
                } else {
                    super::CONTENT_BASE + 1 + rng.below(256) as i32
                };
                if !used.contains(&cand) {
                    used.push(cand);
                    choices.push(vec![cand]);
                }
            }
            let answer = self.shuffle_choices(&mut choices, rng);
            return Example { context, choices, answer };
        }
    }

    /// n-way continuation choice: correct = same-topic continuation,
    /// distractors = continuations under other topics.
    fn choice_continuation(
        &self,
        gen: &Generator,
        rng: &mut Rng,
        n: usize,
        cont_len: usize,
    ) -> Example {
        let (toks, topic) = gen.sequence(rng);
        let ctx_len = toks.len() * 2 / 3;
        let context = toks[..ctx_len].to_vec();
        let last = *context.last().unwrap();
        let mut choices = vec![gen.continuation(rng, last, topic, cont_len)];
        for i in 1..n {
            let alt = (topic + i) % 8;
            choices.push(gen.continuation(rng, last, alt, cont_len));
        }
        let answer = self.shuffle_choices(&mut choices, rng);
        Example { context, choices, answer }
    }

    /// Single-token successor choice: correct = deterministic successor of
    /// the last token under the sequence topic; distractor = successor
    /// under a different topic.
    fn winogrande(&self, gen: &Generator, rng: &mut Rng) -> Example {
        let (toks, topic) = gen.sequence(rng);
        let ctx_len = toks.len() - toks.len() / 4;
        let context = toks[..ctx_len].to_vec();
        let last = *context.last().unwrap();
        let rel = (last - super::CONTENT_BASE - 1).max(0) as usize;
        let correct = super::CONTENT_BASE + 1 + gen.successor(topic, rel) as i32;
        let mut alt = correct;
        let mut t = topic + 1;
        while alt == correct {
            alt = super::CONTENT_BASE + 1 + gen.successor(t % 8, rel) as i32;
            t += 1;
            if t > topic + 16 {
                alt = super::CONTENT_BASE + 1 + rng.below(256) as i32;
            }
        }
        let mut choices = vec![vec![correct], vec![alt]];
        let answer = self.shuffle_choices(&mut choices, rng);
        Example { context, choices, answer }
    }

    /// Shuffle in place; return the new index of the original choice 0.
    fn shuffle_choices(&self, choices: &mut [Vec<i32>], rng: &mut Rng) -> usize {
        let correct = choices[0].clone();
        rng.shuffle(choices);
        choices.iter().position(|c| *c == correct).unwrap()
    }
}

/// Flatten an example into scoring rows `(tokens, mask, choice_len)` —
/// one row per choice, mask over the continuation region. The caller pads
/// to the model sequence length.
pub fn scoring_rows(ex: &Example) -> Vec<(Vec<i32>, Vec<f32>, usize)> {
    ex.choices
        .iter()
        .map(|choice| {
            let mut toks = Vec::with_capacity(ex.context.len() + choice.len());
            toks.push(BOS);
            // Keep the tail of the context if it would overflow: the
            // continuation tokens must always fit.
            toks.extend_from_slice(&ex.context[1.min(ex.context.len())..]);
            toks.extend_from_slice(choice);
            let mut mask = vec![0.0f32; toks.len()];
            let start = toks.len() - choice.len();
            for m in mask.iter_mut().skip(start) {
                *m = 1.0;
            }
            (toks, mask, choice.len())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig { seed: 21, trigger_prob: 1.0, ..CorpusConfig::default() })
    }

    #[test]
    fn examples_are_deterministic() {
        let c = corpus();
        let ts = TaskSet::new(&c);
        for task in Task::ALL {
            let a = ts.examples(c.generator(), task, 5);
            let b = ts.examples(c.generator(), task, 5);
            assert_eq!(a.len(), 5);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context, "{task:?}");
                assert_eq!(x.choices, y.choices);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn choice_counts_match_task() {
        let c = corpus();
        let ts = TaskSet::new(&c);
        for task in Task::ALL {
            for ex in ts.examples(c.generator(), task, 8) {
                assert_eq!(ex.choices.len(), task.n_choices(), "{task:?}");
                assert!(ex.answer < ex.choices.len());
                // All choices distinct (otherwise accuracy is ill-defined).
                for i in 0..ex.choices.len() {
                    for j in i + 1..ex.choices.len() {
                        assert_ne!(ex.choices[i], ex.choices[j], "{task:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn answers_are_shuffled() {
        let c = corpus();
        let ts = TaskSet::new(&c);
        let answers: Vec<usize> = ts
            .examples(c.generator(), Task::Lambada, 40)
            .iter()
            .map(|e| e.answer)
            .collect();
        // Not all in the same slot.
        assert!(answers.iter().any(|&a| a != answers[0]), "{answers:?}");
    }

    #[test]
    fn scoring_rows_mask_exactly_the_choice() {
        let c = corpus();
        let ts = TaskSet::new(&c);
        let ex = &ts.examples(c.generator(), Task::Piqa, 1)[0];
        let rows = scoring_rows(ex);
        assert_eq!(rows.len(), 2);
        for (row, (toks, mask, clen)) in rows.iter().enumerate() {
            assert_eq!(toks.len(), mask.len());
            let masked: f32 = mask.iter().sum();
            assert_eq!(masked as usize, *clen);
            // Masked suffix equals the choice tokens.
            let start = toks.len() - clen;
            assert_eq!(&toks[start..], &ex.choices[row][..]);
        }
    }

    #[test]
    fn random_baseline_matches_paper_floor() {
        let b = suite_random_baseline();
        assert!((b - 0.375).abs() < 1e-12);
    }
}
