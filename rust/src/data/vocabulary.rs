//! Human-readable token surface for CLI demos and logs.
//!
//! The synthetic language is over integer ids; this module gives each id a
//! stable pronounceable name (CV-syllable encoding of the id) so demo
//! output reads like text instead of numbers, and provides the inverse
//! mapping. It deliberately has no effect on modeling — the tokenizer the
//! paper's models use is out of scope for weight-quantization behaviour.

use std::collections::BTreeMap;

use super::{BOS, PAD};
use crate::data::corpus::TRIGGER;

const ONSETS: [&str; 8] = ["b", "d", "f", "k", "l", "m", "n", "s"];
const VOWELS: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ou", "ei"];

/// Bidirectional id <-> surface-form mapping for a vocabulary size.
pub struct Vocabulary {
    names: Vec<String>,
    ids: BTreeMap<String, i32>,
}

impl Vocabulary {
    pub fn new(vocab: usize) -> Self {
        let mut names = Vec::with_capacity(vocab);
        let mut ids = BTreeMap::new();
        for id in 0..vocab as i32 {
            let name = match id {
                x if x == PAD => "<pad>".to_string(),
                x if x == BOS => "<bos>".to_string(),
                x if x == TRIGGER => "<trig>".to_string(),
                _ => Self::syllables(id as usize),
            };
            ids.insert(name.clone(), id);
            names.push(name);
        }
        Vocabulary { names, ids }
    }

    /// Two-syllable CV name, bijective over ids (base-64 digits of the id).
    fn syllables(id: usize) -> String {
        let hi = id / 64;
        let lo = id % 64;
        let syl = |d: usize| format!("{}{}", ONSETS[d / 8], VOWELS[d % 8]);
        format!("{}{}", syl(hi % 64), syl(lo))
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| self.names.get(t as usize).map(|s| s.as_str()).unwrap_or("<unk>"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .filter_map(|w| self.ids.get(w).copied())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_roundtrip() {
        let v = Vocabulary::new(512);
        assert_eq!(v.len(), 512);
        let mut seen = std::collections::BTreeSet::new();
        for id in 0..512 {
            assert!(seen.insert(v.names[id].clone()), "dup name {}", v.names[id]);
        }
        let toks = vec![1, 2, 100, 511];
        let text = v.decode(&toks);
        assert_eq!(v.encode(&text), toks);
    }

    #[test]
    fn special_tokens_have_markers() {
        let v = Vocabulary::new(512);
        let s = v.decode(&[0, 1, 2]);
        assert_eq!(s, "<pad> <bos> <trig>");
    }
}
