//! Data substrate: synthetic corpus, tokenizer surface, zero-shot tasks.
//!
//! The paper evaluates on The Pile CommonCrawl (perplexity) and four
//! LM-eval-harness tasks. Neither is available here (repro band 0/5), so
//! this module implements the closest synthetic equivalents exercising the
//! same code paths (DESIGN.md §1 substitution table):
//!
//! * [`corpus`] — a topic-conditional Zipf–Markov language with planted
//!   long-range dependencies. Larger models fit it strictly better
//!   (topic-conditional transition tables + in-context topic inference),
//!   which is what gives the scaling-law plots their slope.
//! * [`tasks`] — four zero-shot task generators mirroring the metric
//!   structure of LAMBADA, PiQA, HellaSwag and Winogrande (2- and 4-way
//!   choices, single- and multi-token continuations, length-normalized
//!   log-likelihood scoring).
//! * [`vocabulary`] — a tiny named-token surface so CLI demos can print
//!   readable text; model I/O stays in token ids throughout.

pub mod corpus;
pub mod tasks;
pub mod vocabulary;

/// Token id conventions shared across the stack (and with `model.py`,
/// which masks PAD in the training loss).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
/// First id usable as a content token.
pub const CONTENT_BASE: i32 = 2;
