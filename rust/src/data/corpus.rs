//! Topic-conditional Zipf–Markov corpus generator.
//!
//! Each sequence draws a latent **topic**; tokens then follow a mixture of
//! (a) a topic-specific deterministic affine successor map
//! `next = (a_t * cur + b_t) mod V'` and (b) a global Zipf unigram draw.
//! The result has:
//!
//! * a Zipfian marginal (like natural text),
//! * topic-conditional bigram structure a model must devote capacity to —
//!   the component that separates model scales,
//! * long-range dependency: the topic is only identifiable from context,
//!   so better in-context inference (more layers/width) lowers loss,
//! * planted **trigger→payload** pairs: token `TRIGGER` is followed by a
//!   payload `x`, and near the end of the sequence the payload's image
//!   `f_t(x)` reappears — the hook the LAMBADA-like task is built from.
//!
//! Entropy knobs are chosen so tier-t0 underfits and tier-t5 approaches
//! the generator's conditional entropy, giving the scaling plots a slope.

use crate::util::rng::{Rng, Zipf};

use super::{BOS, CONTENT_BASE, PAD};

/// Generator configuration. `vocab`/`seq` must match the AOT manifest.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub seq: usize,
    /// Number of latent topics.
    pub topics: usize,
    /// Probability of following the topic's deterministic successor map
    /// (vs a Zipf unigram draw).
    pub det_prob: f64,
    /// Zipf exponent of the unigram component.
    pub zipf_alpha: f64,
    /// Probability of planting a trigger→payload pair in a sequence.
    pub trigger_prob: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 512,
            seq: 64,
            topics: 8,
            det_prob: 0.75,
            zipf_alpha: 1.1,
            trigger_prob: 0.5,
            seed: 0xC0FFEE,
        }
    }
}

/// The reserved trigger token id.
pub const TRIGGER: i32 = CONTENT_BASE;

/// A generated corpus plus its generator (for on-demand eval batches).
pub struct Corpus {
    pub cfg: CorpusConfig,
    gen: Generator,
}

/// The underlying stochastic process; shared by corpus and task generation.
#[derive(Clone)]
pub struct Generator {
    cfg: CorpusConfig,
    zipf: Zipf,
    /// Per-topic affine successor maps `(a, b)` over the content range.
    maps: Vec<(usize, usize)>,
}

impl Generator {
    pub fn new(cfg: &CorpusConfig) -> Self {
        let content = cfg.vocab - CONTENT_BASE as usize - 1; // exclude PAD/BOS/TRIGGER
        let mut rng = Rng::new(cfg.seed ^ 0x9E37);
        let maps = (0..cfg.topics)
            .map(|_| {
                // `a` odd and coprime-ish with content size for good mixing.
                let a = 1 + 2 * (1 + rng.below(content / 2 - 1));
                let b = rng.below(content);
                (a, b)
            })
            .collect();
        Generator { cfg: cfg.clone(), zipf: Zipf::new(content, cfg.zipf_alpha), maps }
    }

    fn content_size(&self) -> usize {
        self.cfg.vocab - CONTENT_BASE as usize - 1
    }

    /// Map a content-relative token through topic `t`'s successor function.
    pub fn successor(&self, t: usize, cur: usize) -> usize {
        let (a, b) = self.maps[t % self.maps.len()];
        (cur.wrapping_mul(a).wrapping_add(b)) % self.content_size()
    }

    fn to_token(&self, content_rel: usize) -> i32 {
        CONTENT_BASE + 1 + content_rel as i32
    }

    fn from_token(&self, tok: i32) -> usize {
        (tok - CONTENT_BASE - 1) as usize
    }

    /// Generate one full sequence: BOS, body, no padding (len == seq).
    /// Returns `(tokens, topic)`.
    pub fn sequence(&self, rng: &mut Rng) -> (Vec<i32>, usize) {
        let topic = rng.below(self.cfg.topics);
        let mut toks = Vec::with_capacity(self.cfg.seq);
        toks.push(BOS);
        let mut cur = self.zipf.sample(rng);
        toks.push(self.to_token(cur));

        // Optionally plant a trigger→payload at a random early position
        // and remember to emit f_t(payload) near the end.
        let plant = rng.f64() < self.cfg.trigger_prob;
        let trig_pos = 4 + rng.below(self.cfg.seq / 3);
        let mut payload: Option<usize> = None;

        while toks.len() < self.cfg.seq {
            if plant && toks.len() == trig_pos {
                let p = self.zipf.sample(rng);
                toks.push(TRIGGER);
                if toks.len() < self.cfg.seq {
                    toks.push(self.to_token(p));
                }
                payload = Some(p);
                cur = p;
                continue;
            }
            if let Some(p) = payload {
                if toks.len() == self.cfg.seq - 1 {
                    // Final token: the planted long-range completion.
                    toks.push(self.to_token(self.successor(topic, p)));
                    break;
                }
            }
            cur = if rng.f64() < self.cfg.det_prob {
                self.successor(topic, cur)
            } else {
                self.zipf.sample(rng)
            };
            toks.push(self.to_token(cur));
        }
        (toks, topic)
    }

    /// Continue `from` for `len` tokens under `topic` (used by the
    /// multi-token choice tasks).
    pub fn continuation(&self, rng: &mut Rng, from: i32, topic: usize, len: usize) -> Vec<i32> {
        let mut cur = if from > CONTENT_BASE { self.from_token(from) } else { self.zipf.sample(rng) };
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            cur = if rng.f64() < self.cfg.det_prob {
                self.successor(topic, cur)
            } else {
                self.zipf.sample(rng)
            };
            out.push(self.to_token(cur));
        }
        out
    }
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        Corpus { gen: Generator::new(&cfg), cfg }
    }

    /// The repo's one manifest-geometry corpus construction: the given
    /// vocab/seq over every other `CorpusConfig` default (incl. the
    /// seed). The CLI context, the serve-side `{"op":"tune"}` op, and
    /// the tuning benches/tests all build their corpus here, so the
    /// sweep, the tuner, and serving score the same held-out
    /// distribution by construction (the tuning store's dedupe keys
    /// embed the corpus seed and rely on this).
    pub fn for_geometry(vocab: usize, seq: usize) -> Self {
        Corpus::new(CorpusConfig { vocab, seq, ..CorpusConfig::default() })
    }

    pub fn generator(&self) -> &Generator {
        &self.gen
    }

    /// Deterministic batch of training sequences for step `step`
    /// (`batch x seq` row-major i32, PAD-free).
    pub fn train_batch(&self, step: usize, batch: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.cfg.seed ^ (step as u64).wrapping_mul(0x2545F4914F6CDD1D));
        let mut out = Vec::with_capacity(batch * self.cfg.seq);
        for _ in 0..batch {
            let (toks, _) = self.gen.sequence(&mut rng);
            out.extend_from_slice(&toks);
        }
        out
    }

    /// The held-out evaluation split: `n` sequences from a seed range the
    /// training stream can never touch (different stream constant).
    pub fn eval_sequences(&self, n: usize) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(self.cfg.seed ^ 0xEEAA_1234_5678_9ABC);
        (0..n).map(|_| self.gen.sequence(&mut rng).0).collect()
    }

    /// Pad/trim a sequence to `seq` and produce its all-real-tokens mask.
    pub fn pad_to_seq(&self, toks: &[i32]) -> (Vec<i32>, Vec<f32>) {
        pad_score_row(toks, self.cfg.seq)
    }
}

/// The perplexity row-shaping rule, shared by the corpus and the serving
/// layer (which pads to the addressed tier's `seq`): head-truncate to
/// `seq`, pad with [`PAD`], mask every real token as a target except
/// position 0 (BOS is never a target).
pub fn pad_score_row(toks: &[i32], seq: usize) -> (Vec<i32>, Vec<f32>) {
    let mut t = toks.to_vec();
    t.truncate(seq);
    let real = t.len();
    t.resize(seq, PAD);
    let mut mask = vec![0.0f32; seq];
    for m in mask.iter_mut().take(real).skip(1) {
        *m = 1.0;
    }
    (t, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CorpusConfig {
        CorpusConfig { seed: 7, ..CorpusConfig::default() }
    }

    #[test]
    fn sequences_are_deterministic_and_well_formed() {
        let c1 = Corpus::new(small_cfg());
        let c2 = Corpus::new(small_cfg());
        let a = c1.train_batch(3, 4);
        let b = c2.train_batch(3, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4 * 64);
        for &t in &a {
            assert!((0..512).contains(&t), "token {t} out of vocab");
        }
        // Every sequence starts with BOS and contains no PAD.
        for row in a.chunks(64) {
            assert_eq!(row[0], BOS);
            assert!(!row.contains(&PAD));
        }
    }

    #[test]
    fn train_and_eval_streams_differ() {
        let c = Corpus::new(small_cfg());
        let train = c.train_batch(0, 1);
        let eval = &c.eval_sequences(1)[0];
        assert_ne!(&train, eval);
    }

    #[test]
    fn zipfian_marginal() {
        let c = Corpus::new(small_cfg());
        let mut counts = vec![0usize; 512];
        for s in 0..200 {
            for &t in &c.train_batch(s, 1) {
                counts[t as usize] += 1;
            }
        }
        // Head content tokens more frequent per token than tail ones (the
        // deterministic topic maps flatten the marginal, but the Zipf
        // component keeps a clear head/tail separation).
        let head: usize = counts[3..40].iter().sum();
        let tail: usize = counts[400..].iter().sum();
        let head_rate = head as f64 / 37.0;
        let tail_rate = tail as f64 / 112.0;
        assert!(head_rate > tail_rate * 2.0, "head {head_rate:.1} vs tail {tail_rate:.1}");
    }

    #[test]
    fn topics_change_statistics() {
        let cfg = small_cfg();
        let g = Generator::new(&cfg);
        // Successor maps must differ between topics for some input.
        let diffs = (0..100).filter(|&x| g.successor(0, x) != g.successor(1, x)).count();
        assert!(diffs > 50);
    }

    #[test]
    fn padding_and_mask() {
        let c = Corpus::new(small_cfg());
        let (toks, mask) = c.pad_to_seq(&[BOS, 5, 6]);
        assert_eq!(toks.len(), 64);
        assert_eq!(mask.len(), 64);
        assert_eq!(&toks[..3], &[BOS, 5, 6]);
        assert!(toks[3..].iter().all(|&t| t == PAD));
        assert_eq!(mask[0], 0.0);
        assert_eq!(mask[1], 1.0);
        assert_eq!(mask[2], 1.0);
        assert_eq!(mask[3], 0.0);
    }

    #[test]
    fn planted_completion_is_topic_function_of_payload() {
        let cfg = CorpusConfig { trigger_prob: 1.0, seed: 11, ..CorpusConfig::default() };
        let g = Generator::new(&cfg);
        let mut rng = Rng::new(1);
        let (toks, topic) = g.sequence(&mut rng);
        let tpos = toks.iter().position(|&t| t == TRIGGER).expect("trigger planted");
        let payload = toks[tpos + 1];
        let want = g.to_token(g.successor(topic, g.from_token(payload)));
        assert_eq!(*toks.last().unwrap(), want);
    }
}
