//! Model-level GPTQ: one-shot quantization of a whole checkpoint against
//! real calibration activations.
//!
//! The AOT graph `acts_<tier>.hlo.txt` returns each projection's input
//! activations — stacked `(L, B, S, in_dim)` for `qkv`, `wo`, `fc1`,
//! `fc2` — on a calibration batch of corpus sequences. Each layer's matrix
//! is then GPTQ-quantized independently (exactly how per-layer one-shot
//! quantization is defined), producing a dequantized checkpoint that runs
//! through the same forward executable as the zero-shot specs, so Figure 5
//! and Table 1 compare the two method families on equal footing.

use anyhow::{bail, Context, Result};

use crate::data::corpus::Corpus;
use crate::models::manifest::{Manifest, TierManifest};
use crate::quant::QuantSpec;
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use crate::tensor::Tensor;

use super::{gptq_quantize, GptqConfig};

/// The four GPTQ-quantized projections, in `acts` graph output order.
const TARGETS: [&str; 4] = ["qkv", "wo", "fc1", "fc2"];

/// Collect calibration activations for every projection of every layer.
///
/// Returns, per target tensor name, a vec of per-layer activation
/// matrices `(B*S, in_dim)`.
pub fn collect_activations(
    rt: &Runtime,
    manifest: &Manifest,
    tier: &TierManifest,
    params: &[(String, Tensor)],
    corpus: &Corpus,
) -> Result<Vec<(String, Vec<Tensor>)>> {
    let acts_hlo = tier
        .acts_hlo
        .as_ref()
        .context("manifest has no acts graph; rerun `make artifacts`")?;
    let exe = rt.load(&manifest.hlo_path(acts_hlo))?;

    // Calibration batch: held-out-adjacent stream (distinct seed path).
    let b = tier.batch_eval;
    let s = tier.seq;
    let tokens = corpus.train_batch(usize::MAX / 2, b); // far from training steps
    let mut args: Vec<xla::Literal> = Vec::with_capacity(params.len() + 1);
    for (_, t) in params {
        args.push(lit_f32(t)?);
    }
    args.push(lit_i32(&[b, s], &tokens)?);
    let out = rt.execute(&exe, &args)?;
    if out.len() != 4 {
        bail!("acts graph returned {} leaves, expected 4", out.len());
    }

    let l = tier.n_layer;
    let rows = b * s;
    let mut result = Vec::with_capacity(4);
    for (ti, name) in TARGETS.iter().enumerate() {
        let in_dim = match *name {
            "fc2" => tier.d_ff,
            _ => tier.d_model,
        };
        let flat = to_vec_f32(&out[ti])?;
        if flat.len() != l * rows * in_dim {
            bail!("{name} acts: got {} values, expected {}", flat.len(), l * rows * in_dim);
        }
        let per = rows * in_dim;
        let layers: Vec<Tensor> = (0..l)
            .map(|li| Tensor::new(vec![rows, in_dim], flat[li * per..(li + 1) * per].to_vec()))
            .collect();
        result.push((name.to_string(), layers));
    }
    Ok(result)
}

/// GPTQ-quantize a checkpoint under `spec` (dtype/bits/block reused from
/// the zero-shot spec vocabulary; blocking runs along input dims).
pub fn quantize_checkpoint_gptq(
    rt: &Runtime,
    manifest: &Manifest,
    tier: &TierManifest,
    params: &[(String, Tensor)],
    corpus: &Corpus,
    spec: &QuantSpec,
    cfg: &GptqConfig,
) -> Result<Vec<(String, Tensor)>> {
    let acts = collect_activations(rt, manifest, tier, params, corpus)?;
    let acts_by: std::collections::BTreeMap<&str, &Vec<Tensor>> =
        acts.iter().map(|(n, v)| (n.as_str(), v)).collect();

    let mut out = Vec::with_capacity(params.len());
    for (name, t) in params {
        let Some(layer_acts) = acts_by.get(name.as_str()) else {
            out.push((name.clone(), t.clone()));
            continue;
        };
        let shape = t.shape().to_vec(); // (L, in, out)
        let (l, rows, cols) = (shape[0], shape[1], shape[2]);
        let per = rows * cols;
        let mut data = vec![0.0f32; t.len()];
        for li in 0..l {
            let w = Tensor::new(vec![rows, cols], t.data()[li * per..(li + 1) * per].to_vec());
            let q = gptq_quantize(&w, &layer_acts[li], spec, cfg)
                .with_context(|| format!("gptq on {name}[{li}]"))?;
            data[li * per..(li + 1) * per].copy_from_slice(q.data());
        }
        out.push((name.clone(), Tensor::new(shape, data)));
    }
    Ok(out)
}
