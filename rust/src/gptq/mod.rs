//! One-shot GPTQ quantization (Frantar et al., 2022) — the paper's
//! comparison point for Table 1 and Figure 5.
//!
//! GPTQ quantizes a weight matrix column-group by column-group while
//! compensating the not-yet-quantized weights for the error introduced so
//! far, using the Hessian of the layerwise reconstruction objective
//! `H = 2 X Xᵀ` estimated from a calibration mini-batch. This is the
//! "one-shot" (needs data) counterpart to the paper's zero-shot methods;
//! the paper shows GPTQ *with blocking* beats zero-shot 3-bit float
//! (Table 1) while GPTQ *without blocking* scales poorly at 3-bit (Fig 5).
//!
//! The implementation follows the standard Cholesky formulation:
//!
//! 1. `H = 2 X Xᵀ + λI` (dampened),
//! 2. `Hinv = (cholesky(H))⁻¹` upper-triangular inverse,
//! 3. process columns left→right; each weight is rounded to the nearest
//!    codebook value (block-wise absmax normalized, like the zero-shot
//!    path, so GPTQ composes with every data type and block size in this
//!    repo), and the residual is propagated into later columns via the
//!    Hinv row.
//!
//! [`linalg`] provides the dense Cholesky / triangular-inverse substrate.

pub mod linalg;
pub mod model;

use anyhow::{bail, Result};

use crate::quant::codebook::Codebook;
use crate::quant::spec::QuantSpec;
use crate::tensor::Tensor;

/// GPTQ configuration knobs.
#[derive(Debug, Clone)]
pub struct GptqConfig {
    /// Relative Hessian dampening `λ = damp * mean(diag(H))`.
    pub damp: f64,
    /// Columns processed per lazy-update group (perf only).
    pub group_cols: usize,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { damp: 0.01, group_cols: 32 }
    }
}

/// Quantize `w` (shape `(in_dim, out_dim)`, inputs on rows — so the
/// reconstruction objective is over `x @ w`) with GPTQ against calibration
/// activations `x` (shape `(samples, in_dim)`).
///
/// `spec.block` applies along the **input** dimension of each output
/// column, matching the fused-kernel layout, so the returned blocking is
/// directly storable. Returns the dequantized (simulated) weight.
pub fn gptq_quantize(
    w: &Tensor,
    x: &Tensor,
    spec: &QuantSpec,
    cfg: &GptqConfig,
) -> Result<Tensor> {
    let (in_dim, out_dim) = w.dims2()?;
    let (samples, xc) = x.dims2()?;
    if xc != in_dim {
        bail!("calibration width {xc} != weight input dim {in_dim}");
    }
    if samples == 0 {
        bail!("empty calibration batch");
    }
    let codebook = spec.codebook()?;
    let block = spec.block.unwrap_or(in_dim);

    // H = 2/n * XᵀX  (in_dim x in_dim), dampened.
    let mut h = vec![0.0f64; in_dim * in_dim];
    for s in 0..samples {
        let row = &x.data()[s * in_dim..(s + 1) * in_dim];
        for i in 0..in_dim {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in i..in_dim {
                h[i * in_dim + j] += xi * row[j] as f64;
            }
        }
    }
    let scale = 2.0 / samples as f64;
    for i in 0..in_dim {
        for j in i..in_dim {
            let v = h[i * in_dim + j] * scale;
            h[i * in_dim + j] = v;
            h[j * in_dim + i] = v;
        }
    }
    let mean_diag = (0..in_dim).map(|i| h[i * in_dim + i]).sum::<f64>() / in_dim as f64;
    let lambda = cfg.damp * mean_diag.max(1e-12);
    for i in 0..in_dim {
        h[i * in_dim + i] += lambda;
    }

    // Hinv via Cholesky: H = L Lᵀ, Hinv = L⁻ᵀ L⁻¹; we need the upper
    // Cholesky factor of Hinv, which is exactly L⁻ᵀ scaled — the standard
    // GPTQ trick: work with U = chol(Hinv)ᵀ (upper). Diagonal entries of
    // U drive the error feedback.
    // GPTQ needs U = chol(H⁻¹)ᵀ-style upper factor with H⁻¹ = Uᵀ U:
    // diagonal entries drive the error feedback, row i the propagation.
    //   H = L Lᵀ             (lower Cholesky)
    //   H⁻¹ = L⁻ᵀ L⁻¹        (explicit inverse via triangular inverse)
    //   H⁻¹ = Lb Lbᵀ         (second Cholesky of the inverse)
    //   U := Lbᵀ  ⇒  Uᵀ U = Lb Lbᵀ = H⁻¹, U upper triangular.
    let l = linalg::cholesky(&h, in_dim)?;
    let linv = linalg::lower_triangular_inverse(&l, in_dim)?;
    // B = H⁻¹ = Linvᵀ · Linv (symmetric; fill both halves).
    let mut b_inv = vec![0.0f64; in_dim * in_dim];
    for i in 0..in_dim {
        for j in i..in_dim {
            // (Linvᵀ Linv)[i,j] = Σ_k Linv[k,i] · Linv[k,j]; Linv is lower,
            // so only k >= max(i, j) contributes.
            let mut s = 0.0;
            for k in j..in_dim {
                s += linv[k * in_dim + i] * linv[k * in_dim + j];
            }
            b_inv[i * in_dim + j] = s;
            b_inv[j * in_dim + i] = s;
        }
    }
    let lb = linalg::cholesky(&b_inv, in_dim)?;
    let mut u = vec![0.0f64; in_dim * in_dim];
    for i in 0..in_dim {
        for j in i..in_dim {
            u[i * in_dim + j] = lb[j * in_dim + i]; // U = Lbᵀ
        }
    }

    // Work on W transposed per-column? Keep row-major (in_dim rows).
    let mut wq = w.data().to_vec(); // mutated in place, becomes dequantized weight

    // Process input dims sequentially with error feedback.
    // Quantization scales: per (block, out-col) absmax, computed lazily per
    // block from the *current* (error-compensated) weights, matching GPTQ
    // implementations that derive scales group-wise during the pass.
    let nblocks = in_dim.div_ceil(block);
    for b in 0..nblocks {
        let lo = b * block;
        let hi = ((b + 1) * block).min(in_dim);
        // Per-column absmax over this block of input dims.
        let mut amax = vec![0.0f32; out_dim];
        for i in lo..hi {
            for c in 0..out_dim {
                amax[c] = amax[c].max(wq[i * out_dim + c].abs());
            }
        }
        for a in amax.iter_mut() {
            if *a == 0.0 {
                *a = 1.0;
            }
        }
        for i in lo..hi {
            let d = u[i * in_dim + i];
            if d.abs() < 1e-30 {
                bail!("singular Hessian factor at dim {i}");
            }
            // Quantize row i across all output columns; accumulate errors.
            let mut err = vec![0.0f64; out_dim];
            for c in 0..out_dim {
                let wv = wq[i * out_dim + c];
                let qv = codebook.value(codebook.assign(wv / amax[c])) * amax[c];
                err[c] = (wv - qv) as f64 / d;
                wq[i * out_dim + c] = qv;
            }
            // Propagate into the remaining (unquantized) input dims.
            for j in (i + 1)..in_dim {
                let uij = u[i * in_dim + j];
                if uij == 0.0 {
                    continue;
                }
                for c in 0..out_dim {
                    wq[j * out_dim + c] -= (uij * err[c]) as f32;
                }
            }
        }
    }

    Ok(Tensor::new(vec![in_dim, out_dim], wq))
}

/// Round-to-nearest baseline under the same blocking layout (input-dim
/// blocks per output column) for controlled GPTQ-vs-RTN comparisons.
pub fn rtn_quantize(w: &Tensor, spec: &QuantSpec) -> Result<Tensor> {
    let (in_dim, out_dim) = w.dims2()?;
    let codebook: Codebook = spec.codebook()?;
    let block = spec.block.unwrap_or(in_dim);
    let mut out = w.data().to_vec();
    let nblocks = in_dim.div_ceil(block);
    for b in 0..nblocks {
        let lo = b * block;
        let hi = ((b + 1) * block).min(in_dim);
        for c in 0..out_dim {
            let mut amax = 0.0f32;
            for i in lo..hi {
                amax = amax.max(out[i * out_dim + c].abs());
            }
            if amax == 0.0 {
                amax = 1.0;
            }
            for i in lo..hi {
                let v = out[i * out_dim + c];
                out[i * out_dim + c] = codebook.value(codebook.assign(v / amax)) * amax;
            }
        }
    }
    Ok(Tensor::new(vec![in_dim, out_dim], out))
}

/// Layerwise reconstruction error `||x(w - wq)||² / ||x w||²` — the
/// objective GPTQ minimizes; used by tests and the E5 bench.
pub fn reconstruction_error(w: &Tensor, wq: &Tensor, x: &Tensor) -> Result<f64> {
    let (in_dim, out_dim) = w.dims2()?;
    let (samples, _) = x.dims2()?;
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for s in 0..samples {
        let row = &x.data()[s * in_dim..(s + 1) * in_dim];
        for c in 0..out_dim {
            let mut y = 0.0f64;
            let mut yq = 0.0f64;
            for i in 0..in_dim {
                y += row[i] as f64 * w.data()[i * out_dim + c] as f64;
                yq += row[i] as f64 * wq.data()[i * out_dim + c] as f64;
            }
            num += (y - yq) * (y - yq);
            den += y * y;
        }
    }
    Ok(num / den.max(1e-30))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::DataType;
    use crate::util::rng::Rng;

    fn randn(shape: Vec<usize>, seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let n = shape.iter().product();
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        Tensor::new(shape, v)
    }

    /// Calibration with correlated features — the regime where GPTQ's
    /// error compensation matters.
    fn correlated_x(samples: usize, dim: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0f32; samples * dim];
        for s in 0..samples {
            let base = rng.normal() as f32;
            for i in 0..dim {
                data[s * dim + i] = 0.7 * base + 0.3 * rng.normal() as f32;
            }
        }
        Tensor::new(vec![samples, dim], data)
    }

    #[test]
    fn gptq_beats_rtn_at_low_bits() {
        let w = randn(vec![32, 16], 1, 0.1);
        let x = correlated_x(128, 32, 2);
        let spec = QuantSpec::new(DataType::Int, 3, Some(16));
        let g = gptq_quantize(&w, &x, &spec, &GptqConfig::default()).unwrap();
        let r = rtn_quantize(&w, &spec).unwrap();
        let eg = reconstruction_error(&w, &g, &x).unwrap();
        let er = reconstruction_error(&w, &r, &x).unwrap();
        assert!(eg < er, "gptq {eg} !< rtn {er}");
    }

    #[test]
    fn gptq_blocking_beats_no_blocking() {
        // Table 1's mechanism: with outliers present, blocked GPTQ wins.
        let mut w = randn(vec![64, 16], 3, 0.05);
        for c in 0..16 {
            w.data_mut()[5 * 16 + c] *= 25.0; // outlier input dim
        }
        let x = correlated_x(128, 64, 4);
        let blocked = QuantSpec::new(DataType::Int, 2, Some(16));
        let unblocked = QuantSpec::new(DataType::Int, 2, None);
        let gb = gptq_quantize(&w, &x, &blocked, &GptqConfig::default()).unwrap();
        let gu = gptq_quantize(&w, &x, &unblocked, &GptqConfig::default()).unwrap();
        let eb = reconstruction_error(&w, &gb, &x).unwrap();
        let eu = reconstruction_error(&w, &gu, &x).unwrap();
        assert!(eb < eu, "blocked {eb} !< unblocked {eu}");
    }

    #[test]
    fn gptq_high_bits_nearly_lossless() {
        let w = randn(vec![16, 8], 5, 0.1);
        let x = correlated_x(64, 16, 6);
        let spec = QuantSpec::new(DataType::Int, 8, Some(16));
        let g = gptq_quantize(&w, &x, &spec, &GptqConfig::default()).unwrap();
        let e = reconstruction_error(&w, &g, &x).unwrap();
        assert!(e < 1e-4, "8-bit error {e}");
    }

    #[test]
    fn shape_validation() {
        let w = randn(vec![8, 4], 7, 0.1);
        let bad_x = randn(vec![16, 6], 8, 1.0);
        assert!(gptq_quantize(&w, &bad_x, &QuantSpec::new(DataType::Int, 4, None), &GptqConfig::default()).is_err());
    }

    #[test]
    fn rtn_matches_expected_blocking() {
        // A single outlier column block should not disturb other blocks.
        let mut w = randn(vec![32, 4], 9, 0.05);
        w.data_mut()[0] = 10.0;
        let spec = QuantSpec::new(DataType::Int, 4, Some(8));
        let r = rtn_quantize(&w, &spec).unwrap();
        // Error in rows 8.. of column 0 unaffected by the outlier at row 0.
        for i in 8..32 {
            let d = (r.data()[i * 4] - w.data()[i * 4]).abs();
            assert!(d < 0.05, "row {i} err {d}");
        }
    }
}
