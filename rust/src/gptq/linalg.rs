//! Dense linear-algebra substrate for GPTQ: Cholesky factorization and
//! triangular inversion over row-major `f64` matrices. Sizes here are the
//! input dimensions of transformer projections (≤ a few thousand), so a
//! cache-friendly textbook implementation is plenty; no BLAS dependency.

use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` of a symmetric positive-definite
/// `n x n` matrix `a` (row-major). `a = L Lᵀ`.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum})");
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Inverse of a lower-triangular matrix (forward substitution per column).
pub fn lower_triangular_inverse(l: &[f64], n: usize) -> Result<Vec<f64>> {
    assert_eq!(l.len(), n * n);
    let mut inv = vec![0.0f64; n * n];
    for col in 0..n {
        // Solve L x = e_col.
        for i in col..n {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in col..i {
                sum -= l[i * n + k] * inv[k * n + col];
            }
            let d = l[i * n + i];
            if d == 0.0 {
                bail!("singular triangular matrix at {i}");
            }
            inv[i * n + col] = sum / d;
        }
    }
    Ok(inv)
}

/// `C = A Bᵀ` for row-major `A (m x k)`, `B (n x k)` → `C (m x n)`.
/// Used by tests to validate factorizations.
pub fn matmul_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for p in 0..k {
                s += a[i * k + p] * b[j * k + p];
            }
            c[i * n + j] = s;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0.0f64; n * n];
        for v in g.iter_mut() {
            *v = rng.normal();
        }
        // A = G Gᵀ + n * I is SPD.
        let mut a = matmul_nt(&g, &g, n, n, n);
        for i in 0..n {
            a[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        for n in [1usize, 2, 5, 16, 33] {
            let a = random_spd(n, n as u64);
            let l = cholesky(&a, n).unwrap();
            let back = matmul_nt(&l, &l, n, n, n);
            for i in 0..n * n {
                assert!((a[i] - back[i]).abs() < 1e-8, "n={n} i={i}");
            }
            // L is lower triangular.
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l[i * n + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn triangular_inverse_is_inverse() {
        for n in [1usize, 3, 8, 20] {
            let a = random_spd(n, 100 + n as u64);
            let l = cholesky(&a, n).unwrap();
            let linv = lower_triangular_inverse(&l, n).unwrap();
            // L * Linv = I (multiply row-major: L (n x n) x Linv (n x n)).
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += l[i * n + k] * linv[k * n + j];
                    }
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((s - want).abs() < 1e-9, "n={n} ({i},{j}) = {s}");
                }
            }
        }
    }
}
