//! Evaluation harness: perplexity and the four zero-shot tasks.
//!
//! Scoring runs through an [`ExecutionPlan`] (`runtime::plan`): the
//! monolithic `fwd_<tier>.hlo.txt` graph is the degenerate single-stage
//! plan mapping `(params…, tokens, mask)` to per-row `(nll_sum,
//! top1_hits)`; a pipeline-sharded tier chains its stage artifacts by
//! activation handoff instead, with identical scoring semantics.
//! Perplexity masks all real tokens; zero-shot tasks mask the candidate
//! continuation and compare **length-normalized** log-likelihood across
//! choices (the EleutherAI harness's multiple-choice scoring rule).
//!
//! Parameter literals are built **once per quantization cell** and reused
//! across all evaluation batches of that cell — the dominant cost saving
//! of the sweep hot path (EXPERIMENTS.md §Perf).

use anyhow::{bail, Result};

use crate::data::corpus::Corpus;
use crate::data::tasks::{scoring_rows, Task, TaskSet};
use crate::models::manifest::{Manifest, TierManifest};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, ExecutionPlan, Runtime};
use crate::tensor::Tensor;

/// How much evaluation a sweep cell requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvalSuite {
    /// Perplexity only (cheap; the paper's own recommendation for
    /// replication — Section 4).
    Ppl,
    /// Perplexity + all four zero-shot tasks.
    PplZeroShot,
}

/// Evaluation workload sizes.
#[derive(Debug, Clone)]
pub struct EvalConfig {
    /// Held-out sequences for perplexity.
    pub ppl_sequences: usize,
    /// Examples per zero-shot task.
    pub zs_examples: usize,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { ppl_sequences: 48, zs_examples: 48 }
    }
}

/// Full metrics for one evaluated model/quantization cell.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Cross-entropy (nats/token) on the held-out split.
    pub ce: f64,
    /// `exp(ce)` with the paper's instability clamp at 100.
    pub ppl: f64,
    /// Per-task accuracy, `Task::ALL` order (empty for `EvalSuite::Ppl`).
    pub zs_acc: Vec<f64>,
    /// Mean zero-shot accuracy (NaN when not evaluated).
    pub zs_mean: f64,
    /// Greedy next-token accuracy on the ppl split (a bonus diagnostic).
    pub top1: f64,
}

/// The evaluator for one tier: holds the compiled execution plan + batch
/// geometry, and optionally a native fused-kernel backend that scores
/// packed residency directly (bypassing the XLA executables).
pub struct Evaluator<'rt> {
    rt: &'rt Runtime,
    plan: ExecutionPlan,
    tier: TierManifest,
    /// When set (`{"op":"load","fused":true}` variants), every scoring
    /// call routes through the native fused dequant×matmul backend; the
    /// parameter-literal argument is ignored (fused variants keep no XLA
    /// literals resident).
    native: Option<std::sync::Arc<crate::runtime::native::NativeModel>>,
}

impl<'rt> Evaluator<'rt> {
    /// The default evaluator: the monolithic single-stage plan.
    pub fn new(rt: &'rt Runtime, manifest: &Manifest, tier: &TierManifest) -> Result<Self> {
        Evaluator::with_plan(rt, manifest, tier, false)
    }

    /// Evaluator over an explicit plan choice: `pipeline` selects the
    /// tier's declared multi-stage plan (errors if the manifest declares
    /// none); otherwise the monolithic graph.
    pub fn with_plan(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tier: &TierManifest,
        pipeline: bool,
    ) -> Result<Self> {
        let plan = ExecutionPlan::compile(rt, manifest, tier, pipeline)?;
        Ok(Evaluator { rt, plan, tier: tier.clone(), native: None })
    }

    /// Attach the native fused-kernel backend: all scoring (perplexity,
    /// zero-shot, served rows) dispatches to it instead of the XLA plan.
    pub fn set_native(&mut self, model: std::sync::Arc<crate::runtime::native::NativeModel>) {
        self.native = Some(model);
    }

    /// Whether this evaluator scores through the native fused backend.
    pub fn is_native(&self) -> bool {
        self.native.is_some()
    }

    /// The compiled execution plan (stage layout + per-stage geometry).
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Build the reusable parameter literals for a parameter set, in the
    /// plan's flat parameter order (== tier manifest order for the
    /// monolithic plan; per-stage slices for pipeline plans). Generic
    /// over `Borrow<Tensor>` so borrowed (`Cow`) checkpoints from
    /// [`crate::quant::quantize_checkpoint_cow`] avoid f32 copies.
    pub fn param_literals<T: std::borrow::Borrow<Tensor>>(
        &self,
        params: &[(String, T)],
    ) -> Result<Vec<xla::Literal>> {
        if params.len() != self.tier.params.len() {
            bail!("expected {} parameter tensors, got {}", self.tier.params.len(), params.len());
        }
        self.plan.param_literals(params)
    }

    /// Public scoring entry point used by the serving layer: rows must be
    /// padded to the tier sequence length already.
    pub fn score_padded_rows(
        &self,
        plits: &[xla::Literal],
        rows: &[(Vec<i32>, Vec<f32>)],
    ) -> Result<Vec<(f64, f64)>> {
        self.score_rows(plits, rows)
    }

    /// Score a batch of `(tokens, mask)` rows (padded to `batch_eval`);
    /// returns per-row `(nll_sum, hits)` for the first `rows.len()` rows.
    fn score_rows(
        &self,
        plits: &[xla::Literal],
        rows: &[(Vec<i32>, Vec<f32>)],
    ) -> Result<Vec<(f64, f64)>> {
        if let Some(native) = &self.native {
            // Fused variants score natively; `plits` is empty for them.
            return native.score_rows(rows);
        }
        let b = self.tier.batch_eval;
        let s = self.tier.seq;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let mut tokens = vec![0i32; b * s];
            let mut mask = vec![0.0f32; b * s];
            for (r, (t, m)) in chunk.iter().enumerate() {
                assert_eq!(t.len(), s, "rows must be padded to seq");
                tokens[r * s..(r + 1) * s].copy_from_slice(t);
                mask[r * s..(r + 1) * s].copy_from_slice(m);
            }
            let tok_lit = lit_i32(&[b, s], &tokens)?;
            let mask_lit = lit_f32(&Tensor::new(vec![b, s], mask))?;
            // Parameter literals are borrowed: built once per cell, reused
            // across every batch of the cell (the sweep's hot-path saving).
            let res = self.plan.execute(self.rt, plits, &tok_lit, &mask_lit)?;
            if res.len() != 2 {
                bail!("eval plan returned {} leaves, expected 2", res.len());
            }
            let nll = to_vec_f32(&res[0])?;
            let hits = to_vec_f32(&res[1])?;
            for r in 0..chunk.len() {
                out.push((nll[r] as f64, hits[r] as f64));
            }
        }
        Ok(out)
    }

    /// Perplexity (and greedy accuracy) over held-out corpus sequences.
    pub fn perplexity(
        &self,
        plits: &[xla::Literal],
        corpus: &Corpus,
        n_sequences: usize,
    ) -> Result<(f64, f64, f64)> {
        let seqs = corpus.eval_sequences(n_sequences);
        let rows: Vec<(Vec<i32>, Vec<f32>)> =
            seqs.iter().map(|sq| corpus.pad_to_seq(sq)).collect();
        let scored = self.score_rows(plits, &rows)?;
        let mut total_nll = 0.0;
        let mut total_hits = 0.0;
        let mut total_tok = 0.0;
        for ((nll, hits), (_, mask)) in scored.iter().zip(&rows) {
            total_nll += nll;
            total_hits += hits;
            total_tok += mask.iter().sum::<f32>() as f64;
        }
        let ce = total_nll / total_tok.max(1.0);
        // Paper convention: clamp unstable perplexities at 100.
        let ppl = ce.exp().min(100.0);
        Ok((ce, ppl, total_hits / total_tok.max(1.0)))
    }

    /// Accuracy of one zero-shot task via length-normalized LL scoring.
    pub fn zero_shot(
        &self,
        plits: &[xla::Literal],
        corpus: &Corpus,
        task: Task,
        n_examples: usize,
    ) -> Result<f64> {
        let ts = TaskSet::new(corpus);
        let examples = ts.examples(corpus.generator(), task, n_examples);
        // Flatten every choice of every example into rows.
        let mut rows: Vec<(Vec<i32>, Vec<f32>)> = Vec::new();
        let mut lens: Vec<usize> = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::new(); // (start_row, n_choices)
        for ex in &examples {
            let start = rows.len();
            for (toks, mask, clen) in scoring_rows(ex) {
                let (t, m) = pad_row(&toks, &mask, self.tier.seq);
                rows.push((t, m));
                lens.push(clen);
            }
            spans.push((start, ex.choices.len()));
        }
        let scored = self.score_rows(plits, &rows)?;
        let mut correct = 0usize;
        for (ex, &(start, n)) in examples.iter().zip(&spans) {
            // argmax over -nll/len (higher normalized LL wins). NaN-last:
            // a NaN NLL from the executable must not panic the worker; if
            // every choice is NaN the example is unanswerable and that is
            // an error, not a silent guess.
            let norm = |i: usize| -scored[start + i].0 / lens[start + i].max(1) as f64;
            let best = (0..n)
                .max_by(|&a, &b| crate::util::order::nan_last_cmp(norm(a), norm(b)))
                .unwrap();
            if norm(best).is_nan() {
                bail!("non-finite NLL for every choice of a {task:?} example");
            }
            if best == ex.answer {
                correct += 1;
            }
        }
        Ok(correct as f64 / examples.len().max(1) as f64)
    }

    /// Run a full suite for one parameter set.
    pub fn run<T: std::borrow::Borrow<Tensor>>(
        &self,
        params: &[(String, T)],
        corpus: &Corpus,
        suite: EvalSuite,
        cfg: &EvalConfig,
    ) -> Result<EvalResult> {
        let plits = self.param_literals(params)?;
        self.run_literals(&plits, corpus, suite, cfg)
    }

    /// Run a full suite against already-built parameter literals — the
    /// one suite-assembly path shared by [`Evaluator::run`] (the sweep)
    /// and the serving layer's resident-handle calibration (the
    /// autotuner), so the two metrics can never diverge.
    pub fn run_literals(
        &self,
        plits: &[xla::Literal],
        corpus: &Corpus,
        suite: EvalSuite,
        cfg: &EvalConfig,
    ) -> Result<EvalResult> {
        let (ce, ppl, top1) = self.perplexity(plits, corpus, cfg.ppl_sequences)?;
        let mut zs_acc = Vec::new();
        if suite == EvalSuite::PplZeroShot {
            for task in Task::ALL {
                zs_acc.push(self.zero_shot(plits, corpus, task, cfg.zs_examples)?);
            }
        }
        let zs_mean = if zs_acc.is_empty() {
            f64::NAN
        } else {
            zs_acc.iter().sum::<f64>() / zs_acc.len() as f64
        };
        Ok(EvalResult { ce, ppl, zs_acc, zs_mean, top1 })
    }
}

/// Pad/trim a scoring row to the model sequence length, keeping the
/// **tail** (the continuation must survive; early context is droppable).
/// Public because the serving layer shapes `choose` rows with the same
/// rule.
pub fn pad_row(toks: &[i32], mask: &[f32], seq: usize) -> (Vec<i32>, Vec<f32>) {
    let mut t: Vec<i32>;
    let mut m: Vec<f32>;
    if toks.len() > seq {
        let cut = toks.len() - seq;
        t = toks[cut..].to_vec();
        m = mask[cut..].to_vec();
    } else {
        t = toks.to_vec();
        m = mask.to_vec();
        t.resize(seq, crate::data::PAD);
        m.resize(seq, 0.0);
    }
    (t, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_row_keeps_tail() {
        let toks: Vec<i32> = (0..100).collect();
        let mut mask = vec![0.0f32; 100];
        mask[95..].fill(1.0);
        let (t, m) = pad_row(&toks, &mask, 64);
        assert_eq!(t.len(), 64);
        assert_eq!(*t.last().unwrap(), 99);
        assert_eq!(m.iter().sum::<f32>(), 5.0);
        // Short rows pad with PAD/0.
        let (t2, m2) = pad_row(&[1, 2], &[0.0, 1.0], 8);
        assert_eq!(t2, vec![1, 2, 0, 0, 0, 0, 0, 0]);
        assert_eq!(m2[1], 1.0);
        assert_eq!(m2[2..].iter().sum::<f32>(), 0.0);
    }
}
