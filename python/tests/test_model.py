"""Layer-2 model graph checks: shapes, masking semantics, training step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def cfg():
    return model.ModelConfig("test", d_model=32, n_layer=2, n_head=2)


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, jax.random.PRNGKey(0))


def test_param_shapes_and_count(cfg, params):
    shapes = model.param_shapes(cfg)
    assert list(shapes) == list(model.PARAM_NAMES)
    for name, p in zip(model.PARAM_NAMES, params):
        assert p.shape == shapes[name], name
    assert model.param_count(cfg) == sum(int(np.prod(s)) for s in shapes.values())


def test_eval_scores_masking(cfg, params):
    f = model.eval_scores(cfg)
    b, s = 4, cfg.seq
    tokens = jnp.asarray(np.random.default_rng(0).integers(2, cfg.vocab, (b, s)), jnp.int32)
    full = jnp.ones((b, s), jnp.float32)
    half = full.at[:, s // 2 :].set(0.0)
    nll_full, hits = f(*params, tokens, full)
    nll_half, _ = f(*params, tokens, half)
    assert nll_full.shape == (b,)
    assert np.all(np.asarray(nll_half) < np.asarray(nll_full))
    assert np.all(np.asarray(hits) >= 0)
    # Untrained -> close to uniform log-loss per token.
    per_tok = float(jnp.sum(nll_full)) / (b * (s - 1))
    assert abs(per_tok - np.log(cfg.vocab)) < 1.0


def test_position_zero_never_scored(cfg, params):
    f = model.eval_scores(cfg)
    b, s = 2, cfg.seq
    tokens = jnp.asarray(np.random.default_rng(1).integers(2, cfg.vocab, (b, s)), jnp.int32)
    only_bos = jnp.zeros((b, s), jnp.float32).at[:, 0].set(1.0)
    nll, hits = f(*params, tokens, only_bos)
    np.testing.assert_allclose(np.asarray(nll), 0.0)
    np.testing.assert_allclose(np.asarray(hits), 0.0)


def test_train_step_descends(cfg, params):
    step_fn = jax.jit(model.train_step(cfg))
    b = model.BATCH_TRAIN
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(2, cfg.vocab, (b, cfg.seq)), jnp.int32)
    n = len(model.PARAM_NAMES)
    ps = list(params)
    ms = [jnp.zeros_like(p) for p in ps]
    vs = [jnp.zeros_like(p) for p in ps]
    losses = []
    for t in range(1, 21):
        out = step_fn(*ps, *ms, *vs, tokens, jnp.float32(3e-3), jnp.float32(t))
        ps, ms, vs = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    # Overfitting one fixed batch must cut loss sharply.
    assert losses[-1] < losses[0] - 1.0, losses[::5]


def test_calibration_acts_shapes(cfg, params):
    f = jax.jit(model.calibration_acts(cfg))
    b = model.BATCH_EVAL
    tokens = jnp.asarray(np.random.default_rng(3).integers(2, cfg.vocab, (b, cfg.seq)), jnp.int32)
    qkv_in, wo_in, fc1_in, fc2_in = f(*params, tokens)
    L, d, ff = cfg.n_layer, cfg.d_model, cfg.d_ff
    assert qkv_in.shape == (L, b, cfg.seq, d)
    assert wo_in.shape == (L, b, cfg.seq, d)
    assert fc1_in.shape == (L, b, cfg.seq, d)
    assert fc2_in.shape == (L, b, cfg.seq, ff)
    # LayerNormed tap has ~unit rms.
    rms = float(jnp.sqrt(jnp.mean(qkv_in**2)))
    assert 0.3 < rms < 3.0


def test_tiers_are_increasing():
    counts = [model.param_count(c) for c in model.TIERS]
    assert all(a < b for a, b in zip(counts, counts[1:]))
    assert counts[-1] / counts[0] > 50  # >1.5 orders of magnitude
