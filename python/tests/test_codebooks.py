"""Codebook construction properties (Appendix A data types)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import codebooks as cbm


@pytest.mark.parametrize("k", range(2, 9))
def test_int_codebook(k):
    cb = cbm.int_codebook(k)
    assert len(cb) == 2**k - 1  # symmetric truncation
    assert cb[0] == -1.0 and cb[-1] == 1.0
    assert 0.0 in cb
    np.testing.assert_allclose(cb, -cb[::-1], atol=0)  # exactly symmetric


@pytest.mark.parametrize("k", range(3, 9))
def test_fp_codebook_all_exponents(k):
    for e in range(1, k - 1):
        cb = cbm.fp_codebook(k, e)
        assert np.all(np.diff(cb) > 0), "sorted strictly"
        assert np.abs(cb).max() == pytest.approx(1.0)
        assert 0.0 in cb
        # Set size: 2^k patterns minus the duplicated ±0.
        assert 2**k - 2 <= len(cb) <= 2**k


@pytest.mark.parametrize("k", range(3, 9))
def test_dynexp_codebook(k):
    cb = cbm.dynexp_codebook(k)
    assert np.all(np.diff(cb) > 0)
    assert 0.0 in cb
    pos = cb[cb > 0]
    # Smallest positive value is 10^-(k-2) after normalization.
    assert pos.min() == pytest.approx(10.0 ** -(k - 2), rel=1e-3)


def test_quantile_codebook_equal_mass():
    rng = np.random.default_rng(7)
    sample = rng.standard_normal(100_000).astype(np.float32)
    cb = cbm.quantile_codebook(4, sample)
    assert len(cb) == 16
    assert 0.0 in cb
    # Interior bins should hold roughly 1/16 of a fresh sample each. The
    # two extreme entries are midpoints with the distribution tails, so
    # their nearest-neighbour regions legitimately hold less mass.
    fresh = rng.standard_normal(50_000)
    fresh = fresh / np.abs(fresh).max()  # blockwise-style normalization
    edges = (cb[1:] + cb[:-1]) / 2
    counts = np.histogram(fresh, bins=np.concatenate([[-np.inf], edges, [np.inf]]))[0]
    interior = counts[1:-1]
    assert interior.min() > 50_000 / 16 / 4, counts
    assert interior.max() < 50_000 / 16 * 3, counts


def test_quantile_needs_enough_samples():
    with pytest.raises(ValueError):
        cbm.quantile_codebook(8, np.zeros(10))


def test_default_exponent_heuristic():
    assert cbm.default_exponent_bits(3) == 2
    for k in range(4, 9):
        assert cbm.default_exponent_bits(k) == 3


@settings(max_examples=20, deadline=None)
@given(dtype=st.sampled_from(cbm.DTYPES), k=st.integers(3, 8))
def test_make_codebook_normalized_sorted(dtype, k):
    cb = cbm.make_codebook(dtype, k)
    assert np.abs(cb).max() == pytest.approx(1.0)
    assert np.all(np.diff(cb) > 0)
    assert len(cb) <= 2**k + 1
