"""Pallas fused dequant-matmul kernels vs the pure-jnp/numpy oracle —
the core Layer-1 correctness signal, including hypothesis sweeps over
shapes, data types, block sizes, and tile geometries."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import codebooks as cbm
from compile.kernels import ref
from compile.kernels.dequant_matmul import (
    DEFAULT_TILES,
    dequant_matmul_packed4,
    dequant_matmul_u8,
    matmul_f32,
    vmem_report,
)

RNG = np.random.default_rng(0xBEEF)


def quantize_case(dtype, k, K, N, block, scale=1.0):
    w = (RNG.standard_normal((K, N)) * scale).astype(np.float32)
    cb = cbm.make_codebook(dtype, k)
    idx, amax = ref.quantize_colblock(w, cb, block)
    return w, cb, idx, amax


@pytest.mark.parametrize("dtype", cbm.DTYPES)
def test_u8_kernel_matches_oracle(dtype):
    x = RNG.standard_normal((16, 128)).astype(np.float32)
    _, cb, idx, amax = quantize_case(dtype, 4, 128, 256, 64)
    cbp = np.concatenate([cb, np.full(256 - len(cb), cb[-1], np.float32)])
    got = np.asarray(dequant_matmul_u8(x, idx, amax, cbp, qblock=64))
    want = ref.dequant_matmul_ref(x, idx, amax, cb, 64)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_packed4_kernel_matches_oracle():
    x = RNG.standard_normal((16, 128)).astype(np.float32)
    _, cb, idx, amax = quantize_case("fp", 4, 128, 256, 64)
    packed = ref.pack4(idx)
    cbp = np.concatenate([cb, np.zeros(256 - len(cb), np.float32)])
    got = np.asarray(dequant_matmul_packed4(x, packed, amax, cbp, qblock=64))
    want = ref.dequant_matmul_ref(x, idx, amax, cb, 64)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_f32_baseline_kernel():
    x = RNG.standard_normal((16, 128)).astype(np.float32)
    w = RNG.standard_normal((128, 256)).astype(np.float32)
    got = np.asarray(matmul_f32(x, w))
    np.testing.assert_allclose(got, x @ w, atol=1e-3, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    dtype=st.sampled_from(cbm.DTYPES),
    k=st.sampled_from([3, 4, 5, 8]),
    qblock=st.sampled_from([16, 32, 64]),
    mk=st.sampled_from([(16, 64, 128), (32, 128, 128), (16, 192, 256)]),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
)
def test_u8_kernel_hypothesis(dtype, k, qblock, mk, scale):
    m, K, N = mk
    x = RNG.standard_normal((m, K)).astype(np.float32)
    _, cb, idx, amax = quantize_case(dtype, k, K, N, qblock, scale)
    cbp = np.concatenate([cb, np.full(256 - len(cb), cb[-1], np.float32)])
    tiles = (16, 64, 128)
    got = np.asarray(dequant_matmul_u8(x, idx, amax, cbp, qblock=qblock, tiles=tiles))
    want = ref.dequant_matmul_ref(x, idx, amax, cb, qblock)
    tol = max(1e-4, 2e-5 * scale * K)
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-3)


def test_kernel_rejects_bad_geometry():
    x = np.zeros((16, 100), np.float32)  # K not divisible by bk
    wq = np.zeros((100, 128), np.uint8)
    amax = np.ones((2, 128), np.float32)
    cb = np.zeros(256, np.float32)
    with pytest.raises(ValueError):
        dequant_matmul_u8(x, wq, amax, cb, qblock=50)


def test_vmem_report_structure():
    r = vmem_report(512, 512, 4, 64)
    assert r["bits_per_param"] == 4.25
    assert r["bits_loaded_ratio_vs_f32"] == pytest.approx(32 / 4.25)
    bm, bk, bn = DEFAULT_TILES
    # VMEM residency must stay under a sane TPU budget (16 MiB/core).
    assert r["vmem_tile_bytes"] < 16 * 2**20
    assert r["mxu_tile"] == (bm, bk, bn)
