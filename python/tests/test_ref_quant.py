"""Reference blockwise quantizer invariants + packing round trips."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import codebooks as cbm
from compile.kernels import ref

RNG = np.random.default_rng(5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3000),
    block=st.sampled_from([16, 64, 256, 1024]),
    dtype=st.sampled_from(cbm.DTYPES),
    k=st.sampled_from([3, 4, 8]),
)
def test_flat_roundtrip_bounded(n, block, dtype, k):
    x = (RNG.standard_normal(n) * 0.1).astype(np.float32)
    cb = cbm.make_codebook(dtype, k)
    idx, amax = ref.quantize_flat(x, cb, block)
    assert idx.shape == (n,)
    assert len(amax) == -(-n // block)
    back = ref.dequantize_flat(idx, amax, cb, (n,), block)
    gaps = np.diff(cb)
    worst = max(gaps.max() / 2, 1 - abs(cb[0]), 1 - abs(cb[-1]))
    bound = np.repeat(amax, block)[:n] * (worst + 1e-5) + 1e-6
    assert np.all(np.abs(x - back) <= bound)


def test_zero_tensor_roundtrips_exactly():
    cb = cbm.make_codebook("fp", 4)
    x = np.zeros(200, np.float32)
    idx, amax = ref.quantize_flat(x, cb, 64)
    back = ref.dequantize_flat(idx, amax, cb, (200,), 64)
    np.testing.assert_array_equal(back, x)


def test_colblock_matches_flat_per_column():
    # A (block, 1) column tensor: colblock == flat on that column.
    cb = cbm.make_codebook("int", 4)
    w = RNG.standard_normal((64, 1)).astype(np.float32)
    ci, ca = ref.quantize_colblock(w, cb, 64)
    fi, fa = ref.quantize_flat(w[:, 0], cb, 64)
    np.testing.assert_array_equal(ci[:, 0], fi)
    np.testing.assert_allclose(ca[0, 0], fa[0])


def test_colblock_outlier_isolation():
    cb = cbm.make_codebook("int", 4)
    w = (RNG.standard_normal((128, 4)) * 0.05).astype(np.float32)
    w[0, 0] = 50.0  # outlier in column 0, block 0
    idx, amax = ref.quantize_colblock(w, cb, 64)
    back = ref.dequantize_colblock(idx, amax, cb, 64)
    # Column 1 and block 1 of column 0 are unaffected.
    np.testing.assert_allclose(back[:, 1], w[:, 1], atol=0.02)
    np.testing.assert_allclose(back[64:, 0], w[64:, 0], atol=0.02)


@settings(max_examples=15, deadline=None)
@given(k2=st.integers(1, 64), n=st.integers(1, 64))
def test_pack4_roundtrip(k2, n):
    idx = RNG.integers(0, 16, size=(k2 * 2, n)).astype(np.uint8)
    np.testing.assert_array_equal(ref.unpack4(ref.pack4(idx)), idx)


def test_pack4_validation():
    with pytest.raises(ValueError):
        ref.pack4(np.zeros((3, 2), np.uint8))  # odd rows
    with pytest.raises(ValueError):
        ref.pack4(np.full((2, 2), 16, np.uint8))  # > 4 bits


def test_assign_ties_break_low():
    cb = np.array([-1.0, 0.0, 1.0], np.float32)
    # 0.5 is exactly between 0 and 1 -> lower index wins (rust parity).
    assert ref.assign(np.array([0.5], np.float32), cb)[0] == 1
    assert ref.assign(np.array([0.50001], np.float32), cb)[0] == 2
