"""Pure-jnp/numpy reference oracle for block-wise quantization.

Two block layouts are implemented:

  * **flat blocking** (``quantize_flat`` / ``dequantize_flat``) -- the
    paper's Section 2.3 definition: the tensor is viewed as a 1-D sequence,
    chunked into blocks of ``block`` values, and each block is quantized
    independently against its own absmax.  This is the layout the Rust
    run-time quant library implements; the pytest parity suite checks the
    two against golden vectors.

  * **column blocking** (``quantize_colblock`` / ``dequant_matmul_ref``) --
    the layout the fused Pallas dequant-matmul kernel consumes: a weight
    ``W`` of shape ``(K, N)`` is blocked along ``K`` within each column, so
    the absmax tensor has shape ``(K // block, N)`` and one scale row is
    loaded alongside each VMEM tile (DESIGN.md Section 5).

Both layouts share the same index-assignment rule (Eq. 1): nearest codebook
entry after normalizing the block into ``[-1, 1]``.  Codebooks are sorted,
so assignment uses ``searchsorted`` + a one-step neighbour comparison rather
than an argmin over the full set.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "assign",
    "quantize_flat",
    "dequantize_flat",
    "quantize_colblock",
    "dequantize_colblock",
    "dequant_matmul_ref",
    "pack4",
    "unpack4",
]


def assign(normalized: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    """Map each normalized value to the index of the nearest codebook entry.

    ``codebook`` must be sorted ascending.  Ties break toward the lower
    index (matches the Rust implementation).
    """
    cb = np.asarray(codebook, dtype=np.float32)
    x = np.asarray(normalized, dtype=np.float32)
    hi = np.searchsorted(cb, x, side="left").clip(1, len(cb) - 1)
    lo = hi - 1
    pick_hi = np.abs(cb[hi] - x) < np.abs(x - cb[lo])
    return np.where(pick_hi, hi, lo).astype(np.uint8)


def _absmax(blocks: np.ndarray) -> np.ndarray:
    amax = np.abs(blocks).max(axis=-1)
    # A zero block normalizes to zeros with any positive scale.
    return np.where(amax == 0.0, 1.0, amax).astype(np.float32)


def quantize_flat(x: np.ndarray, codebook: np.ndarray, block: int):
    """Paper-layout block-wise quantization of an arbitrary tensor.

    Returns ``(idx, absmax)`` where ``idx`` is ``uint8`` of ``x.size``
    entries (padded blocks are trimmed) and ``absmax`` has one ``float32``
    per block.  ``x.size`` does not need to divide ``block``; the trailing
    partial block is quantized against its own absmax.
    """
    flat = np.asarray(x, dtype=np.float32).ravel()
    n = flat.size
    pad = (-n) % block
    padded = np.pad(flat, (0, pad)).reshape(-1, block)
    amax = _absmax(padded)
    idx = assign(padded / amax[:, None], codebook).ravel()[:n]
    return idx, amax


def dequantize_flat(
    idx: np.ndarray, absmax: np.ndarray, codebook: np.ndarray, shape, block: int
) -> np.ndarray:
    cb = np.asarray(codebook, dtype=np.float32)
    flat = cb[idx.ravel()]
    n = flat.size
    pad = (-n) % block
    padded = np.pad(flat, (0, pad)).reshape(-1, block)
    out = (padded * absmax[:, None]).ravel()[:n]
    return out.reshape(shape).astype(np.float32)


def quantize_colblock(w: np.ndarray, codebook: np.ndarray, block: int):
    """Kernel-layout quantization of a ``(K, N)`` weight.

    Blocks run along ``K`` within each column; returns ``(idx, absmax)``
    with ``idx`` shaped ``(K, N)`` uint8 and ``absmax`` shaped
    ``(K // block, N)`` float32.  ``K`` must be a multiple of ``block``.
    """
    w = np.asarray(w, dtype=np.float32)
    k, n = w.shape
    if k % block != 0:
        raise ValueError(f"K={k} not a multiple of block={block}")
    blocks = w.reshape(k // block, block, n).transpose(0, 2, 1)  # (kb, N, block)
    amax = _absmax(blocks)  # (kb, N)
    idx = assign(blocks / amax[..., None], codebook)
    idx = idx.transpose(0, 2, 1).reshape(k, n)
    return idx, amax


def dequantize_colblock(
    idx: np.ndarray, absmax: np.ndarray, codebook: np.ndarray, block: int
) -> np.ndarray:
    cb = np.asarray(codebook, dtype=np.float32)
    k, n = idx.shape
    vals = cb[idx].reshape(k // block, block, n)
    return (vals * absmax[:, None, :]).reshape(k, n).astype(np.float32)


def dequant_matmul_ref(
    x: np.ndarray,
    idx: np.ndarray,
    absmax: np.ndarray,
    codebook: np.ndarray,
    block: int,
) -> np.ndarray:
    """Oracle for the fused kernel: dequantize ``W`` then ``x @ W``."""
    w = dequantize_colblock(idx, absmax, codebook, block)
    return np.asarray(x, dtype=np.float32) @ w


def pack4(idx: np.ndarray) -> np.ndarray:
    """Pack 4-bit indices two-per-byte along ``K`` (rows).

    Row ``2r`` goes to the low nibble and row ``2r + 1`` to the high nibble
    of output row ``r`` -- the layout the ``packed4`` Pallas kernel unpacks.
    """
    idx = np.asarray(idx, dtype=np.uint8)
    if idx.ndim != 2 or idx.shape[0] % 2 != 0:
        raise ValueError(f"pack4 needs an even-row 2-D index tensor, got {idx.shape}")
    if idx.max(initial=0) > 15:
        raise ValueError("pack4 given indices wider than 4 bits")
    lo = idx[0::2]
    hi = idx[1::2]
    return (lo | (hi << 4)).astype(np.uint8)


def unpack4(packed: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack4`."""
    packed = np.asarray(packed, dtype=np.uint8)
    k2, n = packed.shape
    out = np.empty((k2 * 2, n), dtype=np.uint8)
    out[0::2] = packed & 0xF
    out[1::2] = packed >> 4
    return out
