"""Quantization codebook construction for the four data types of the paper.

A k-bit quantization data type is fully specified by its codebook: the set
``F`` of ``2**k`` floating-point values in ``[-1, 1]`` that the k-bit integer
indices map onto (Appendix A of the paper).  This module builds those
codebooks for:

  * ``int``     -- symmetric linear (uniform) quantization,
  * ``fp``      -- ExMy floating point (FP8-style, no NaN/Inf patterns),
  * ``dynexp``  -- dynamic-exponent data type (Dettmers, 2016),
  * ``quantile``-- information-theoretically optimal quantile quantization
                   (data dependent; estimated from an input sample).

The same codebooks are re-implemented in Rust (``rust/src/quant/codebook.rs``)
for the run-time hot path; ``aot.py`` dumps the vectors produced here to
``artifacts/codebooks.json`` so the Rust unit tests can assert bit-exact
parity with this reference implementation.

All codebooks are returned **sorted ascending** and normalized so that
``max(|F|) == 1`` (the paper's storage-domain convention), which lets the
quantizer use ``searchsorted`` instead of an argmin over the whole set.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "int_codebook",
    "fp_codebook",
    "dynexp_codebook",
    "quantile_codebook",
    "make_codebook",
    "default_exponent_bits",
    "DTYPES",
]

DTYPES = ("int", "fp", "quantile", "dynexp")


def int_codebook(k: int) -> np.ndarray:
    """Symmetric linear integer codebook.

    Follows the paper's convention of truncating the asymmetric two's
    complement range to an equal number of positive and negative values
    around zero: for Int8 the values are ``[-127, ..., 127] / 127``.  One of
    the ``2**k`` bit patterns is therefore unused (the codebook has
    ``2**k - 1`` entries).
    """
    if not 2 <= k <= 8:
        raise ValueError(f"int codebook needs 2 <= k <= 8, got {k}")
    m = 2 ** (k - 1) - 1
    vals = np.arange(-m, m + 1, dtype=np.float64) / m
    return vals.astype(np.float32)


def default_exponent_bits(k: int) -> int:
    """Paper heuristic (Appendix C.4): a 3-bit exponent for 4..8-bit floats
    and a 2-bit exponent for 3-bit floats.  (The appendix notes a 2-bit
    exponent also performs well across precisions; Figure 12 sweeps this.)
    """
    if k <= 3:
        return 2
    return 3


def fp_codebook(k: int, exponent_bits: int | None = None) -> np.ndarray:
    """ExMy floating-point codebook (FP8-style, Micikevicius et al. 2022).

    Layout: 1 sign bit, ``E`` exponent bits, ``M = k - 1 - E`` mantissa bits.
    Bias is ``2**(E-1)`` (paper Section 2.2).  No patterns are reserved for
    NaN/Inf -- every bit pattern is a value.  Exponent field 0 encodes
    subnormals.  The resulting set is normalized to ``[-1, 1]``.
    """
    if exponent_bits is None:
        exponent_bits = default_exponent_bits(k)
    e, m = exponent_bits, k - 1 - exponent_bits
    if e < 1 or m < 0:
        raise ValueError(f"invalid fp layout: k={k} exponent_bits={exponent_bits}")
    bias = 2 ** (e - 1)
    vals = set()
    for sign in (1.0, -1.0):
        for exp_field in range(2**e):
            for man_field in range(2**m):
                frac = man_field / (2**m)
                if exp_field == 0:  # subnormal
                    v = sign * (2.0 ** (1 - bias)) * frac
                else:
                    v = sign * (2.0 ** (exp_field - bias)) * (1.0 + frac)
                vals.add(v)
    arr = np.array(sorted(vals), dtype=np.float64)
    arr /= np.abs(arr).max()
    return arr.astype(np.float32)


def dynexp_codebook(k: int) -> np.ndarray:
    """Dynamic-exponent codebook (Dettmers, 2016; Dettmers et al., 2022b).

    Bit layout: 1 sign bit, then a run of ``z`` zero bits whose length is the
    base-10 exponent magnitude, then an indicator ``1`` bit, then the
    remaining ``f = k - 2 - z`` bits as an unsigned linear fraction.  The
    fraction bits bisect the interval ``(0.1, 0.9]`` into ``2**f`` equal
    steps (the appendix's constructive definition); the value is
    ``sign * 10**-z * frac``.  The all-zero pattern encodes exactly 0.
    The set is normalized to ``[-1, 1]``.
    """
    if not 3 <= k <= 8:
        raise ValueError(f"dynexp codebook needs 3 <= k <= 8, got {k}")
    vals = {0.0}
    for sign in (1.0, -1.0):
        # z zero bits then an indicator bit leaves f = k - 2 - z fraction bits.
        for z in range(0, k - 1):
            f = k - 2 - z
            n = 2**f
            for i in range(n):
                frac = 0.1 + (0.9 - 0.1) * (i + 1) / n
                vals.add(sign * (10.0**-z) * frac)
    arr = np.array(sorted(vals), dtype=np.float64)
    arr /= np.abs(arr).max()
    return arr.astype(np.float32)


def quantile_codebook(k: int, sample: np.ndarray) -> np.ndarray:
    """Quantile quantization codebook estimated from ``sample`` (Eq. 6).

    ``q_i = (Q_X(i / (2**k + 1)) + Q_X((i+1) / (2**k + 1))) / 2`` where
    ``Q_X`` is the empirical quantile function of the sample.  Following the
    paper, an exact 0 is added to the set; to keep ``|F| == 2**k`` we replace
    the entry closest to zero with 0 instead of growing the set.  Normalized
    to ``[-1, 1]``.
    """
    if sample.size < 2**k:
        raise ValueError(f"need at least {2**k} samples for a {k}-bit quantile codebook")
    flat = np.asarray(sample, dtype=np.float64).ravel()
    n = 2**k
    probs_lo = np.arange(n) / (n + 1)
    probs_hi = np.arange(1, n + 1) / (n + 1)
    q = 0.5 * (np.quantile(flat, probs_lo) + np.quantile(flat, probs_hi))
    q = np.sort(q)
    # Anchor an exact zero on the entry nearest to it.
    q[np.argmin(np.abs(q))] = 0.0
    amax = np.abs(q).max()
    if amax == 0.0:
        raise ValueError("degenerate sample: all quantiles are zero")
    q /= amax
    return q.astype(np.float32)


def make_codebook(
    dtype: str,
    k: int,
    sample: np.ndarray | None = None,
    exponent_bits: int | None = None,
) -> np.ndarray:
    """Dispatch helper used by the reference quantizer and by ``aot.py``."""
    if dtype == "int":
        return int_codebook(k)
    if dtype == "fp":
        return fp_codebook(k, exponent_bits)
    if dtype == "dynexp":
        return dynexp_codebook(k)
    if dtype == "quantile":
        if sample is None:
            # Deterministic standard-normal sample: weights are near-normal,
            # so this is the "generic" quantile codebook used when no tensor
            # sample is supplied (Rust mirrors this with the same seed).
            rng = np.random.default_rng(0x5EED)
            sample = rng.standard_normal(65536)
        return quantile_codebook(k, sample)
    raise ValueError(f"unknown dtype {dtype!r}; expected one of {DTYPES}")
