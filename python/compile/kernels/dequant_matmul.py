"""Pallas fused block-wise dequantize + matmul kernels (Layer 1).

The paper's latency argument (Section 2.1) is that small-batch inference is
memory bound: the time to load ``W`` dominates, so storing ``W`` in k bits
and dequantizing on the fly cuts latency roughly by ``16 / k``.  These
kernels are the TPU-style instantiation of that idea (DESIGN.md Section 5):

  * weights live in HBM as ``uint8`` codebook indices (or two 4-bit indices
    per byte for the ``packed4`` variant),
  * ``BlockSpec`` streams ``(bk, bn)`` weight tiles into VMEM; the
    quantization block size divides ``bk`` so each tile carries exactly the
    absmax rows it needs,
  * the ≤256-entry codebook is VMEM-resident for the whole kernel -- the
    gather that is awkward on GPUs (thread serialization through shared
    memory, paper Section 7) is a plain VPU gather here,
  * dequantized tiles feed the MXU via ``jnp.dot``.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and real-TPU
performance is estimated analytically (DESIGN.md Section 7, EXPERIMENTS.md
Section Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "dequant_matmul_u8",
    "dequant_matmul_packed4",
    "matmul_f32",
    "DEFAULT_TILES",
]

# (bm, bk, bn) tile shape. bk is the VMEM streaming dimension and must be a
# multiple of the quantization block size.
DEFAULT_TILES = (16, 64, 128)


def _dequant_tile(idx_u8, amax_tile, cb, bk: int, qblock: int):
    """Dequantize a ``(bk, bn)`` tile of codebook indices.

    ``amax_tile`` is ``(bk // qblock, bn)``: one scale per quantization
    block per column.  The gather ``cb[idx]`` is the VMEM codebook lookup.
    """
    w = cb[idx_u8]  # (bk, bn) gather from the VMEM-resident codebook
    bn = w.shape[-1]
    w = w.reshape(bk // qblock, qblock, bn) * amax_tile[:, None, :]
    return w.reshape(bk, bn)


def _u8_kernel(x_ref, wq_ref, amax_ref, cb_ref, o_ref, *, bk: int, qblock: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(wq_ref[...], amax_ref[...], cb_ref[...], bk, qblock)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _packed4_kernel(x_ref, wq_ref, amax_ref, cb_ref, o_ref, *, bk: int, qblock: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = wq_ref[...]  # (bk // 2, bn): two 4-bit indices per byte
    bn = packed.shape[-1]
    lo = packed & 0xF
    hi = packed >> 4
    # Row 2r is the low nibble, row 2r+1 the high nibble (ref.pack4 layout).
    idx = jnp.stack([lo, hi], axis=1).reshape(bk, bn)
    w = _dequant_tile(idx, amax_ref[...], cb_ref[...], bk, qblock)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _check(m, k, n, qblock, tiles):
    bm, bk, bn = tiles
    if m % bm or k % bk or n % bn:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by tiles {tiles}")
    if bk % qblock:
        raise ValueError(f"tile bk={bk} must be a multiple of qblock={qblock}")


@functools.partial(jax.jit, static_argnames=("qblock", "tiles"))
def dequant_matmul_u8(x, wq, amax, codebook, *, qblock: int = 64, tiles=DEFAULT_TILES):
    """``x @ dequant(wq)`` with one ``uint8`` codebook index per weight.

    Args:
      x:        ``(M, K)`` float32 activations.
      wq:       ``(K, N)`` uint8 codebook indices.
      amax:     ``(K // qblock, N)`` float32 per-block absmax scales.
      codebook: ``(C,)`` float32 sorted codebook, ``C <= 256``.
    """
    m, k = x.shape
    _, n = wq.shape
    _check(m, k, n, qblock, tiles)
    bm, bk, bn = tiles
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_u8_kernel, bk=bk, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // qblock, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(codebook.shape, lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wq, amax, codebook)


@functools.partial(jax.jit, static_argnames=("qblock", "tiles"))
def dequant_matmul_packed4(x, wq_packed, amax, codebook, *, qblock: int = 64, tiles=DEFAULT_TILES):
    """``x @ dequant(wq)`` with two 4-bit indices packed per byte along K.

    ``wq_packed`` is ``(K // 2, N)`` uint8 -- the genuine 4x bits-loaded
    reduction over an f32 weight (plus ``16 / qblock`` bits/param of absmax).
    """
    m, k = x.shape
    n = wq_packed.shape[1]
    if wq_packed.shape[0] * 2 != k:
        raise ValueError(f"packed rows {wq_packed.shape[0]} != K/2 = {k // 2}")
    _check(m, k, n, qblock, tiles)
    bm, bk, bn = tiles
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_packed4_kernel, bk=bk, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // qblock, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(codebook.shape, lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wq_packed, amax, codebook)


def _f32_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tiles",))
def matmul_f32(x, w, *, tiles=DEFAULT_TILES):
    """Unquantized Pallas matmul baseline for the latency benchmark (E14)."""
    m, k = x.shape
    _, n = w.shape
    _check(m, k, n, tiles[1], tiles)
    bm, bk, bn = tiles
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _f32_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_report(k: int, n: int, kbits: int, qblock: int = 64, tiles=DEFAULT_TILES) -> dict:
    """Analytic VMEM-footprint / bits-loaded estimate for a (K, N) layer.

    interpret=True gives no TPU wall-clock, so DESIGN.md Section 7 records
    these structural numbers instead: VMEM bytes per tile residency and the
    HBM bits-loaded ratio versus an f32 weight (the quantity the paper's
    latency claim is proportional to).
    """
    bm, bk, bn = tiles
    idx_bytes = bk * bn * (1 if kbits > 4 else 0.5 if kbits == 4 else kbits / 8)
    amax_bytes = (bk // qblock) * bn * 4
    cb_bytes = (2**kbits) * 4
    x_bytes = bm * bk * 4
    o_bytes = bm * bn * 4
    vmem = idx_bytes + amax_bytes + cb_bytes + x_bytes + o_bytes
    w_bits = k * n * (kbits + 16.0 / qblock)
    f32_bits = k * n * 32.0
    return {
        "vmem_tile_bytes": int(vmem),
        "bits_per_param": kbits + 16.0 / qblock,
        "bits_loaded_ratio_vs_f32": f32_bits / w_bits,
        "mxu_tile": (bm, bk, bn),
    }
