"""Layer 2: decoder-only transformer forward/backward graphs in JAX.

These graphs are lowered **once** by ``aot.py`` to HLO text and executed
from the Rust coordinator via PJRT; Python never runs at request time.
Consequences for how this module is written:

  * Parameters travel as a **flat tuple of 12 stacked tensors** in the fixed
    order of :data:`PARAM_NAMES` -- per-layer weights are stacked along a
    leading ``n_layer`` axis and consumed with ``lax.scan``, so the argument
    list (and the Rust-side checkpoint layout) is depth independent.
  * All shapes are static per :class:`ModelConfig`; the Rust side reads them
    from ``artifacts/manifest.json``.
  * The quantization study simulates k-bit weights by feeding
    quantize->dequantize'd f32 parameters into the *same* forward
    executable, exactly mirroring the paper's protocol (16-bit inputs,
    k-bit weights, computation in high precision after dequantization).

Two entry points are lowered per model scale:

  * :func:`eval_scores`  -- masked negative-log-likelihood sums + greedy
    top-1 hit counts, serving both perplexity and all four zero-shot tasks.
  * :func:`train_step`   -- one fused Adam step (loss, grads, moment and
    parameter updates) driven by the Rust training loop, which owns the
    learning-rate schedule and data order.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "ModelConfig",
    "PARAM_NAMES",
    "QUANTIZED_PARAMS",
    "param_shapes",
    "param_count",
    "eval_scores",
    "fwd_stage_a",
    "fwd_stage_b",
    "pipeline_mid",
    "STACKED_PARAMS",
    "train_step",
    "init_params",
    "TIERS",
    "VOCAB",
    "SEQ",
    "BATCH_TRAIN",
    "BATCH_EVAL",
]

VOCAB = 512
SEQ = 64
BATCH_TRAIN = 8
BATCH_EVAL = 16

#: Fixed parameter order; index into the flat tuple == position in this list.
PARAM_NAMES = (
    "embed",  # (V, d)   token embedding, tied with the LM head
    "pos",  # (S, d)   learned positional embedding
    "qkv",  # (L, d, 3d) fused attention projection         [quantized]
    "wo",  # (L, d, d)  attention output projection          [quantized]
    "fc1",  # (L, d, f)  MLP up projection                   [quantized]
    "fc2",  # (L, f, d)  MLP down projection                 [quantized]
    "ln1_s",  # (L, d)
    "ln1_b",  # (L, d)
    "ln2_s",  # (L, d)
    "ln2_b",  # (L, d)
    "lnf_s",  # (d,)
    "lnf_b",  # (d,)
)

#: Tensors the paper quantizes: FFN + attention projections only
#: (Section 4: "Attention matrices are not quantized"; embeddings and
#: LayerNorm stay 16-bit).
QUANTIZED_PARAMS = ("qkv", "wo", "fc1", "fc2")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration for one model scale ("tier")."""

    name: str
    d_model: int
    n_layer: int
    n_head: int
    vocab: int = VOCAB
    seq: int = SEQ

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


#: The six scales of the synthetic families (DESIGN.md Section 1): ~45k to
#: ~3.7M parameters, spanning almost two orders of magnitude.
TIERS: Sequence[ModelConfig] = (
    ModelConfig("t0", d_model=32, n_layer=2, n_head=2),
    ModelConfig("t1", d_model=48, n_layer=3, n_head=3),
    ModelConfig("t2", d_model=64, n_layer=4, n_head=4),
    ModelConfig("t3", d_model=96, n_layer=5, n_head=6),
    ModelConfig("t4", d_model=128, n_layer=6, n_head=8),
    ModelConfig("t5", d_model=192, n_layer=8, n_head=12),
)


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layer
    return {
        "embed": (cfg.vocab, d),
        "pos": (cfg.seq, d),
        "qkv": (L, d, 3 * d),
        "wo": (L, d, d),
        "fc1": (L, d, f),
        "fc2": (L, f, d),
        "ln1_s": (L, d),
        "ln1_b": (L, d),
        "ln2_s": (L, d),
        "ln2_b": (L, d),
        "lnf_s": (d,),
        "lnf_b": (d,),
    }


def param_count(cfg: ModelConfig) -> int:
    import math

    return sum(math.prod(s) for s in param_shapes(cfg).values())


def init_params(cfg: ModelConfig, key) -> tuple[jnp.ndarray, ...]:
    """Reference initializer (scaled-normal), used by the pytest suite only.

    The run-time initializer lives in Rust (``models::init``) so that family
    recipes -- including emergent-outlier injection -- are applied without
    Python.  Both use std ``0.02`` embeddings and ``0.02 / sqrt(2 L)``-scaled
    residual projections (GPT-2 convention).
    """
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(PARAM_NAMES))
    out = []
    resid_scale = 0.02 / jnp.sqrt(2.0 * cfg.n_layer)
    for k, name in zip(keys, PARAM_NAMES):
        shape = shapes[name]
        if name.endswith("_s"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name in ("wo", "fc2"):
            out.append(jax.random.normal(k, shape, jnp.float32) * resid_scale)
        else:
            out.append(jax.random.normal(k, shape, jnp.float32) * 0.02)
    return tuple(out)


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * scale + bias


def _block(x, layer_params, cfg: ModelConfig):
    """Pre-LN transformer block over ``x: (B, S, d)``."""
    qkv_w, wo_w, fc1_w, fc2_w, ln1_s, ln1_b, ln2_s, ln2_b = layer_params
    b, s, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim

    y = _layernorm(x, ln1_s, ln1_b)
    qkv = y @ qkv_w  # (B, S, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    att = jnp.where(causal[None, None], att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + y @ wo_w

    y = _layernorm(x, ln2_s, ln2_b)
    y = jax.nn.gelu(y @ fc1_w)
    x = x + y @ fc2_w
    return x


def _logits(params, tokens, cfg: ModelConfig):
    """Forward pass to LM logits; scan over stacked per-layer parameters."""
    (embed, pos, qkv, wo, fc1, fc2, l1s, l1b, l2s, l2b, lfs, lfb) = params
    x = embed[tokens] + pos[None]

    def step(carry, lp):
        return _block(carry, lp, cfg), None

    x, _ = lax.scan(step, x, (qkv, wo, fc1, fc2, l1s, l1b, l2s, l2b))
    x = _layernorm(x, lfs, lfb)
    return x @ embed.T  # tied LM head


def _scores_from_logits(logits, tokens, mask):
    """Masked NLL sum + greedy top-1 hit count from LM logits.

    Shared by the monolithic eval graph and the final pipeline stage so a
    sharded plan scores with the exact arithmetic of the one-graph path.
    """
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # predicts tokens[:,1:]
    targets = tokens[:, 1:]
    m = mask[:, 1:]
    tgt_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    nll = -(tgt_logp * m).sum(axis=-1)  # (B,)
    top1 = (jnp.argmax(logp, axis=-1) == targets).astype(jnp.float32)
    hits = (top1 * m).sum(axis=-1)  # (B,)
    return nll, hits


def _masked_nll(params, tokens, mask, cfg: ModelConfig):
    """Per-sequence masked NLL sum and greedy top-1 hit count.

    ``mask[b, s]`` weights the prediction of ``tokens[b, s]`` from position
    ``s - 1``; position 0 is never a target (its mask entry is ignored).
    """
    return _scores_from_logits(_logits(params, tokens, cfg), tokens, mask)


def eval_scores(cfg: ModelConfig):
    """Build the eval entry point ``f(*params, tokens, mask) -> (nll, hits)``.

    One executable serves every metric in the study: perplexity (mask = 1 on
    all real tokens) and the four zero-shot tasks (mask = 1 on the scored
    continuation region; per-choice length normalization happens in Rust).
    """

    def f(*args):
        params = args[: len(PARAM_NAMES)]
        tokens, mask = args[len(PARAM_NAMES)], args[len(PARAM_NAMES) + 1]
        return _masked_nll(params, tokens, mask, cfg)

    return f


# ---------------------------------------------------------------------------
# Pipeline-sharded eval graphs
# ---------------------------------------------------------------------------
#
# The monolithic ``eval_scores`` graph caps the model size one executable
# (and one process) can host.  The 2-stage split below shards the forward
# at a layer boundary ``mid``; each stage is lowered to its own HLO
# artifact and chained at run time by the Rust ``runtime::plan`` engine.
#
# Uniform stage calling convention (what the Rust side relies on):
#
#   stage_i(*stage_params, *carried, tokens, mask) -> carried'
#
# where ``carried`` is the previous stage's output tuple (empty for stage
# 0) and the final stage returns ``(nll, hits)``.  Stacked per-layer
# parameters are sliced ``[:mid]`` / ``[mid:]`` along the leading layer
# axis — a contiguous slice of the checkpoint tensor on the Rust side.
# The tied LM head means ``embed`` appears in both stages (real pipeline
# deployments replicate tied embeddings the same way).

#: Layer-stacked parameter names (leading ``n_layer`` axis), in the order
#: each stage's scan consumes them.
STACKED_PARAMS = ("qkv", "wo", "fc1", "fc2", "ln1_s", "ln1_b", "ln2_s", "ln2_b")


def pipeline_mid(cfg: ModelConfig) -> int:
    """The layer boundary of the 2-stage split (first stage gets [0, mid))."""
    return cfg.n_layer // 2


def fwd_stage_a(cfg: ModelConfig):
    """Stage A: ``(embed, pos, *stacked[:mid], tokens, mask) -> (hidden,)``.

    Embeds tokens and runs the first ``mid`` transformer blocks; the
    hidden state ``(B, S, d)`` is the activation handed to stage B.
    """

    def f(*args):
        embed, pos = args[0], args[1]
        stacked = args[2:10]
        tokens, mask = args[10], args[11]
        x = embed[tokens] + pos[None]

        def step(carry, lp):
            return _block(carry, lp, cfg), None

        x, _ = lax.scan(step, x, stacked)
        # Keep `mask` alive: the stablehlo->XlaComputation conversion drops
        # unused parameters (see calibration_acts), which would break the
        # uniform (params..., carried..., tokens, mask) stage signature.
        keep = jnp.float32(0.0) * jnp.sum(mask)
        return (x + keep,)

    return f


def fwd_stage_b(cfg: ModelConfig):
    """Stage B: ``(*stacked[mid:], lnf_s, lnf_b, embed, hidden, tokens,
    mask) -> (nll, hits)`` — the remaining blocks, final LayerNorm, tied
    LM head, and the same masked scoring arithmetic as ``eval_scores``."""

    def f(*args):
        stacked = args[:8]
        lfs, lfb, embed = args[8], args[9], args[10]
        h, tokens, mask = args[11], args[12], args[13]

        def step(carry, lp):
            return _block(carry, lp, cfg), None

        x, _ = lax.scan(step, h, stacked)
        x = _layernorm(x, lfs, lfb)
        return _scores_from_logits(x @ embed.T, tokens, mask)

    return f


def _stacked_slice_struct(cfg: ModelConfig, name: str, n_layers: int):
    shape = param_shapes(cfg)[name]
    return jax.ShapeDtypeStruct((n_layers, *shape[1:]), jnp.float32)


def stage_a_example_args(cfg: ModelConfig, batch: int = BATCH_EVAL):
    shapes = param_shapes(cfg)
    mid = pipeline_mid(cfg)
    params = [
        jax.ShapeDtypeStruct(shapes["embed"], jnp.float32),
        jax.ShapeDtypeStruct(shapes["pos"], jnp.float32),
    ]
    params += [_stacked_slice_struct(cfg, nm, mid) for nm in STACKED_PARAMS]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.float32)
    return (*params, tokens, mask)


def stage_b_example_args(cfg: ModelConfig, batch: int = BATCH_EVAL):
    shapes = param_shapes(cfg)
    rest = cfg.n_layer - pipeline_mid(cfg)
    params = [_stacked_slice_struct(cfg, nm, rest) for nm in STACKED_PARAMS]
    params += [
        jax.ShapeDtypeStruct(shapes["lnf_s"], jnp.float32),
        jax.ShapeDtypeStruct(shapes["lnf_b"], jnp.float32),
        jax.ShapeDtypeStruct(shapes["embed"], jnp.float32),
    ]
    hidden = jax.ShapeDtypeStruct((batch, cfg.seq, cfg.d_model), jnp.float32)
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.float32)
    return (*params, hidden, tokens, mask)


def _block_with_taps(x, layer_params, cfg: ModelConfig):
    """Like :func:`_block` but also returns the inputs of each projection —
    the calibration activations GPTQ's Hessian is built from (one-shot
    quantization, Frantar et al. 2022; used by E5/Table 1/Figure 5)."""
    qkv_w, wo_w, fc1_w, fc2_w, ln1_s, ln1_b, ln2_s, ln2_b = layer_params
    b, s, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim

    y = _layernorm(x, ln1_s, ln1_b)
    qkv_in = y
    qkv = y @ qkv_w
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    att = jnp.where(causal[None, None], att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    wo_in = y
    x = x + y @ wo_w

    y = _layernorm(x, ln2_s, ln2_b)
    fc1_in = y
    y = jax.nn.gelu(y @ fc1_w)
    fc2_in = y
    x = x + y @ fc2_w
    return x, (qkv_in, wo_in, fc1_in, fc2_in)


def calibration_acts(cfg: ModelConfig):
    """Build ``f(*params, tokens) -> (qkv_in, wo_in, fc1_in, fc2_in)``,
    each stacked ``(L, B, S, in_dim)`` — the per-layer projection inputs
    for GPTQ calibration. Lowered once per tier as ``acts_<tier>.hlo.txt``.
    """

    def f(*args):
        params = args[: len(PARAM_NAMES)]
        tokens = args[len(PARAM_NAMES)]
        (embed, pos, qkv, wo, fc1, fc2, l1s, l1b, l2s, l2b, lfs, lfb) = params
        x = embed[tokens] + pos[None]

        def step(carry, lp):
            new_x, taps = _block_with_taps(carry, lp, cfg)
            return new_x, taps

        _, taps = lax.scan(step, x, (qkv, wo, fc1, fc2, l1s, l1b, l2s, l2b))
        # Keep lnf_s/lnf_b alive: the stablehlo->XlaComputation conversion
        # drops unused parameters, which would desync the Rust-side
        # argument list (all graphs share the 12-param signature).
        keep = jnp.float32(0.0) * (jnp.sum(lfs) + jnp.sum(lfb))
        qkv_in, wo_in, fc1_in, fc2_in = taps
        return (qkv_in + keep, wo_in, fc1_in, fc2_in)

    return f


def acts_example_args(cfg: ModelConfig, batch: int = BATCH_EVAL):
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[nm], jnp.float32) for nm in PARAM_NAMES]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    return (*params, tokens)


def _train_loss(params, tokens, cfg: ModelConfig):
    mask = (tokens != 0).astype(jnp.float32)
    nll, _ = _masked_nll(params, tokens, mask, cfg)
    denom = jnp.maximum(mask[:, 1:].sum(), 1.0)
    return nll.sum() / denom


def train_step(cfg: ModelConfig, beta1=0.9, beta2=0.999, eps=1e-8):
    """Build ``f(*params, *m, *v, tokens, lr, t) -> (*params', *m', *v', loss)``.

    A single fused Adam step.  The Rust driver owns the schedule: it passes
    the current learning rate and (1-based) step index ``t`` for bias
    correction, and round-trips the optimizer state as plain tensors.
    """
    n = len(PARAM_NAMES)

    def f(*args):
        params = args[:n]
        m = args[n : 2 * n]
        v = args[2 * n : 3 * n]
        tokens, lr, t = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        loss, grads = jax.value_and_grad(lambda p: _train_loss(p, tokens, cfg))(params)
        c1 = 1.0 - beta1**t
        c2 = 1.0 - beta2**t
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = beta1 * mi + (1.0 - beta1) * g
            vi = beta2 * vi + (1.0 - beta2) * jnp.square(g)
            update = (mi / c1) / (jnp.sqrt(vi / c2) + eps)
            new_p.append(p - lr * update)
            new_m.append(mi)
            new_v.append(vi)
        return (*new_p, *new_m, *new_v, loss)

    return f


def eval_example_args(cfg: ModelConfig, batch: int = BATCH_EVAL):
    shapes = param_shapes(cfg)
    params = [jax.ShapeDtypeStruct(shapes[nm], jnp.float32) for nm in PARAM_NAMES]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.float32)
    return (*params, tokens, mask)


def train_example_args(cfg: ModelConfig, batch: int = BATCH_TRAIN):
    shapes = param_shapes(cfg)
    ps = [jax.ShapeDtypeStruct(shapes[nm], jnp.float32) for nm in PARAM_NAMES]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return (*ps, *ps, *ps, tokens, scalar, scalar)
