"""AOT compiler: lower every Layer-1/2 graph to HLO text artifacts.

Run via ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).
This is the **only** time Python executes; the Rust coordinator afterwards
loads the emitted ``*.hlo.txt`` files through the PJRT C API and owns
training, quantization, and evaluation end to end.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emitted artifacts:

  fwd_<tier>.hlo.txt     eval_scores graph per model scale
  fwd_a_<tier>.hlo.txt   pipeline stage A (embed + layers [0, mid))
  fwd_b_<tier>.hlo.txt   pipeline stage B (layers [mid, L) + head + scoring)
  train_<tier>.hlo.txt   fused Adam train-step graph per model scale
  dequant_matmul_u8.hlo.txt       fused Pallas dequant+matmul (uint8 idx)
  dequant_matmul_packed4.hlo.txt  fused Pallas dequant+matmul (4-bit packed)
  matmul_f32.hlo.txt              unquantized Pallas matmul baseline
  manifest.json          shapes / argument order / kernel geometry for Rust
  codebooks.json         golden codebook vectors for Rust parity tests
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import codebooks as cbm
from compile.kernels import dequant_matmul as dmm

# Fixed geometry for the standalone fused-kernel artifacts (E14 latency bench).
KERNEL_M, KERNEL_K, KERNEL_N = 16, 512, 512
KERNEL_QBLOCK = 64
CODEBOOK_PAD = 256  # pad every codebook to 256 entries -> one HLO for all dtypes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def stage_entries(cfg) -> list[dict]:
    """Manifest description of the 2-stage pipeline split for one tier.

    Each stage lists the tier parameters it owns; ``lo``/``hi`` select a
    leading-layer-axis slice of a stacked tensor (absent = whole tensor).
    The Rust ``runtime::plan`` engine turns this into an ExecutionPlan;
    the tied LM head means ``embed`` is replicated into stage B.
    """
    mid = model.pipeline_mid(cfg)
    lo_half = [{"source": nm, "lo": 0, "hi": mid} for nm in model.STACKED_PARAMS]
    hi_half = [
        {"source": nm, "lo": mid, "hi": cfg.n_layer} for nm in model.STACKED_PARAMS
    ]
    return [
        {
            "name": "s0",
            "hlo": f"fwd_a_{cfg.name}.hlo.txt",
            "outputs": 1,
            "params": [{"source": "embed"}, {"source": "pos"}, *lo_half],
        },
        {
            "name": "s1",
            "hlo": f"fwd_b_{cfg.name}.hlo.txt",
            "outputs": 2,
            "params": [
                *hi_half,
                {"source": "lnf_s"},
                {"source": "lnf_b"},
                {"source": "embed"},
            ],
        },
    ]


def lower_model_graphs(out_dir: pathlib.Path, tiers) -> list[dict]:
    entries = []
    for cfg in tiers:
        fwd = jax.jit(model.eval_scores(cfg)).lower(*model.eval_example_args(cfg))
        (out_dir / f"fwd_{cfg.name}.hlo.txt").write_text(to_hlo_text(fwd))

        step = jax.jit(model.train_step(cfg)).lower(*model.train_example_args(cfg))
        (out_dir / f"train_{cfg.name}.hlo.txt").write_text(to_hlo_text(step))

        acts = jax.jit(model.calibration_acts(cfg)).lower(*model.acts_example_args(cfg))
        (out_dir / f"acts_{cfg.name}.hlo.txt").write_text(to_hlo_text(acts))

        stage_a = jax.jit(model.fwd_stage_a(cfg)).lower(*model.stage_a_example_args(cfg))
        (out_dir / f"fwd_a_{cfg.name}.hlo.txt").write_text(to_hlo_text(stage_a))
        stage_b = jax.jit(model.fwd_stage_b(cfg)).lower(*model.stage_b_example_args(cfg))
        (out_dir / f"fwd_b_{cfg.name}.hlo.txt").write_text(to_hlo_text(stage_b))

        shapes = model.param_shapes(cfg)
        entries.append(
            {
                "name": cfg.name,
                "d_model": cfg.d_model,
                "n_layer": cfg.n_layer,
                "n_head": cfg.n_head,
                "d_ff": cfg.d_ff,
                "vocab": cfg.vocab,
                "seq": cfg.seq,
                "batch_train": model.BATCH_TRAIN,
                "batch_eval": model.BATCH_EVAL,
                "param_count": model.param_count(cfg),
                "params": [
                    {"name": nm, "shape": list(shapes[nm])} for nm in model.PARAM_NAMES
                ],
                "quantized_params": list(model.QUANTIZED_PARAMS),
                "fwd_hlo": f"fwd_{cfg.name}.hlo.txt",
                "train_hlo": f"train_{cfg.name}.hlo.txt",
                "acts_hlo": f"acts_{cfg.name}.hlo.txt",
                "stages": stage_entries(cfg),
            }
        )
        print(f"  lowered {cfg.name}: {model.param_count(cfg):,} params")
    return entries


def lower_kernels(out_dir: pathlib.Path) -> dict:
    m, k, n, qb = KERNEL_M, KERNEL_K, KERNEL_N, KERNEL_QBLOCK
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    wq = jax.ShapeDtypeStruct((k, n), jnp.uint8)
    wq4 = jax.ShapeDtypeStruct((k // 2, n), jnp.uint8)
    amax = jax.ShapeDtypeStruct((k // qb, n), jnp.float32)
    cb = jax.ShapeDtypeStruct((CODEBOOK_PAD,), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)

    lowered = jax.jit(lambda *a: dmm.dequant_matmul_u8(*a, qblock=qb)).lower(x, wq, amax, cb)
    (out_dir / "dequant_matmul_u8.hlo.txt").write_text(to_hlo_text(lowered))

    lowered = jax.jit(lambda *a: dmm.dequant_matmul_packed4(*a, qblock=qb)).lower(
        x, wq4, amax, cb
    )
    (out_dir / "dequant_matmul_packed4.hlo.txt").write_text(to_hlo_text(lowered))

    lowered = jax.jit(dmm.matmul_f32).lower(x, w)
    (out_dir / "matmul_f32.hlo.txt").write_text(to_hlo_text(lowered))

    print(f"  lowered fused kernels ({m}x{k}x{n}, qblock={qb})")
    return {
        "m": m,
        "k": k,
        "n": n,
        "qblock": qb,
        "codebook_pad": CODEBOOK_PAD,
        "tiles": list(dmm.DEFAULT_TILES),
        "u8_hlo": "dequant_matmul_u8.hlo.txt",
        "packed4_hlo": "dequant_matmul_packed4.hlo.txt",
        "f32_hlo": "matmul_f32.hlo.txt",
        "vmem_report_4bit": dmm.vmem_report(k, n, 4, qb),
        "vmem_report_3bit": dmm.vmem_report(k, n, 3, qb),
        "vmem_report_8bit": dmm.vmem_report(k, n, 8, qb),
    }


def dump_codebooks(out_dir: pathlib.Path) -> None:
    """Golden codebook vectors: Rust `quant::codebook` tests assert parity."""
    out: dict[str, list[float]] = {}
    for k in range(2, 9):
        out[f"int_{k}"] = cbm.int_codebook(k).tolist()
    for k in range(3, 9):
        for e in range(1, k - 1):
            out[f"fp_{k}_e{e}"] = cbm.fp_codebook(k, e).tolist()
        out[f"dynexp_{k}"] = cbm.dynexp_codebook(k).tolist()
        out[f"quantile_{k}"] = cbm.make_codebook("quantile", k).tolist()
    (out_dir / "codebooks.json").write_text(json.dumps(out))
    print(f"  dumped {len(out)} golden codebooks")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--tiers",
        default="all",
        help="comma-separated tier names to lower (default: all)",
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    tiers = model.TIERS
    if args.tiers != "all":
        want = set(args.tiers.split(","))
        tiers = [c for c in model.TIERS if c.name in want]

    print("lowering model graphs...")
    tier_entries = lower_model_graphs(out_dir, tiers)
    print("lowering fused kernels...")
    kernel_entry = lower_kernels(out_dir)
    dump_codebooks(out_dir)

    manifest = {
        "version": 1,
        "vocab": model.VOCAB,
        "seq": model.SEQ,
        "param_names": list(model.PARAM_NAMES),
        "tiers": tier_entries,
        "kernels": kernel_entry,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote manifest with {len(tier_entries)} tiers to {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
