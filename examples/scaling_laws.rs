//! End-to-end driver: the full system on a real (small) workload.
//!
//! This is the repo's E2E validation (DESIGN.md, EXPERIMENTS.md §E2E):
//!
//! 1. generate the synthetic corpus,
//! 2. **train** a family of transformers (t0..t2 by default) by driving
//!    the AOT fused-Adam executable from Rust, logging the loss curve,
//! 3. **quantize** each checkpoint at k ∈ {3, 4, 8, 16},
//! 4. **evaluate** perplexity + the four zero-shot tasks through the AOT
//!    forward executable,
//! 5. fit bit-level scaling curves and report which precision wins at
//!    matched total-bits budgets (the paper's Figure 1 question).
//!
//! Run: `make artifacts && cargo run --release --example scaling_laws`
//! Append `-- full` for tiers t0..t3 and all four headline families.

use kbitscale::bench_support::BenchEnv;
use kbitscale::coordinator::GridBuilder;
use kbitscale::report::figures::bit_curves;
use kbitscale::scaling::{best_curve_at, win_counts};

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "full");
    let env = BenchEnv::open()?;

    let families: Vec<&'static str> =
        if full { vec!["optlike", "pythialike", "gpt2like", "bloomlike"] } else { vec!["gpt2like"] };
    let tiers: Vec<String> = if full {
        kbitscale::bench_support::default_tiers()
    } else {
        ["t0", "t1", "t2"].iter().map(|s| s.to_string()).collect()
    };

    println!("== e2e: train → quantize → evaluate → scaling law ==");
    println!("families: {families:?}, tiers: {tiers:?}\n");

    // Steps 1-2: training (skipped for checkpoints that already exist).
    env.ensure_trained(&families, &tiers)?;

    // Steps 3-4: the quantization sweep (cached in runs/results.jsonl).
    let gb = GridBuilder::new(families.clone(), tiers);
    let cells = gb.bit_scaling(&[3, 4, 8, 16]);
    let results = env.run_grid_timed("e2e", &cells)?;

    // Step 5: scaling analysis.
    println!("\nper-cell results:");
    println!(
        "{:<12} {:<4} {:>6} {:>9} {:>9} {:>8} {:>12}",
        "family", "tier", "bits", "ce", "ppl", "zs_mean", "total_bits"
    );
    let mut sorted = results.clone();
    sorted.sort_by(|a, b| {
        (a.family.clone(), a.tier.clone(), a.bits_per_param.partial_cmp(&b.bits_per_param).unwrap())
            .partial_cmp(&(b.family.clone(), b.tier.clone(), std::cmp::Ordering::Equal))
            .unwrap()
    });
    for r in &sorted {
        println!(
            "{:<12} {:<4} {:>6.2} {:>9.4} {:>9.2} {:>8.3} {:>12.3e}",
            r.family, r.tier, r.bits_per_param, r.ce, r.ppl, r.zs_mean, r.total_bits
        );
    }

    for family in &families {
        let curves = bit_curves(&results, Some(family));
        if curves.len() < 2 {
            continue;
        }
        println!(
            "\n{}",
            kbitscale::report::ascii_chart(
                &format!("bit-level scaling — {family} (zero-shot vs total bits)"),
                "total model bits",
                "mean zero-shot accuracy",
                &curves,
                68,
                14
            )
        );
        let wins = win_counts(&curves, 30);
        println!("precision wins across 30 matched bit budgets: {wins:?}");
        if let Some((best, acc)) = best_curve_at(&curves, 2.0e6) {
            println!("at a 2M-bit budget the best precision is {best} (acc {acc:.3})");
        }
    }

    println!("\nE2E complete. Loss curves are in the training logs above (or");
    println!("rerun with KBITSCALE_LOG=info); results cached in runs/results.jsonl.");
    Ok(())
}
