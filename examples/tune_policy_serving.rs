//! Autotuned serving walkthrough: `tune` → policy artifact → `--policy`
//! serving (the live control surface over the paper's frontier).
//!
//! 1. train a small slice of the zoo (cached after the first run),
//! 2. run the precision autotuner over the k-bit config space on a
//!    calibration slice, deduped into `runs/tune.jsonl`,
//! 3. write the Pareto-frontier policy to `runs/policy.json` — the same
//!    artifact `kbitscale tune` emits and `kbitscale serve --policy`
//!    loads,
//! 4. serve with the policy active and resolve `{"op":"load","auto":true}`
//!    at two different byte budgets: the tight budget lands on the
//!    narrowest quantized frontier config (the k-bit regime where the
//!    paper's 4-bit headline lives), the loose one on the best-metric
//!    config the budget allows.
//!
//! Run: `make artifacts && cargo run --release --example tune_policy_serving`
//!
//! The shell equivalent of steps 2-4:
//! ```text
//! kbitscale train --families gpt2like --tiers t0,t1
//! kbitscale tune  --families gpt2like --tiers t0,t1 --out runs/policy.json
//! kbitscale serve --policy runs/policy.json --max-resident-bytes 30000 --tcp 127.0.0.1:7878
//! echo '{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}' | nc 127.0.0.1 7878
//! ```

use kbitscale::bench_support::BenchEnv;
use kbitscale::models::families::Family;
use kbitscale::models::ModelId;
use kbitscale::server::{Connection, ModelRegistry, ParamLoader};
use kbitscale::tensor::Tensor;
use kbitscale::tune::{self, TuneStore, TuneTarget, TunedPolicy};
use kbitscale::util::json::Json;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let families = vec!["gpt2like"];
    let tiers: Vec<String> = ["t0", "t1"].iter().map(|s| s.to_string()).collect();

    println!("== autotuned serving: search -> policy -> auto-load ==\n");
    env.ensure_trained(&families, &tiers)?;

    // Step 2: the search. Candidates span bits x dtype x block (plus
    // per-stage width vectors when the artifacts declare pipeline
    // stages); each one is built as a real packed resident and scored on
    // the calibration slice. The store makes reruns incremental.
    let store = TuneStore::open(env.paths().results.with_file_name("tune.jsonl"))?;
    let ckpt = &env.checkpoints;
    let loader = |family: &str, tier: &str| -> anyhow::Result<Vec<(String, Tensor)>> {
        let fam = Family::get(family)?;
        Ok(ckpt.load(&ModelId::new(fam.name, tier))?.0)
    };
    let targets: Vec<TuneTarget> =
        tiers.iter().map(|t| TuneTarget::new("gpt2like", t.clone())).collect();
    let cfg = tune::TuneConfig::default(); // bits {3,4,8} x fp/b64 + stage mixes
    let report = tune::search(
        &env.ctx.rt,
        &env.ctx.manifest,
        &env.ctx.corpus,
        &loader,
        &targets,
        &cfg,
        Some(&store),
    )?;
    println!(
        "measured {} cells ({} cached); frontier:",
        report.points.len(),
        report.cached
    );
    for e in &report.policy.entries {
        println!(
            "  {:<28} {:>6.2} bits/param   metric {:+.4}",
            e.key(),
            e.bits_per_param,
            e.metric
        );
    }

    // Step 3: the artifact. `validate()` re-checks the Pareto invariant
    // on every load, so this file is safe to hand-edit.
    let policy_path = env.paths().results.with_file_name("policy.json");
    report.policy.save(&policy_path)?;
    println!("\npolicy -> {}", policy_path.display());
    let policy = TunedPolicy::load(&policy_path)?;

    // Step 4: policy-driven serving at two budgets derived from the
    // frontier itself (measured bits/param includes the 16-bit
    // pass-through tensors, so budgets must come from the entries, not
    // the analytic k+16/B figure). The registry's --max-resident-bytes
    // headroom is what the auto-load pick sees.
    let tier = env.ctx.manifest.tier("t0")?;
    let tight = policy.entries.first().expect("non-empty frontier").estimated_model_bytes(tier);
    let loose = policy.entries.last().unwrap().estimated_model_bytes(tier);
    for (label, budget) in [("tight", tight), ("loose", loose)] {
        let loader: ParamLoader<'_> = Box::new(|family: &str, tier: &str| {
            let fam = Family::get(family)?;
            Ok(ckpt.load(&ModelId::new(fam.name, tier))?.0)
        });
        let registry = ModelRegistry::new(&env.ctx.rt, &env.ctx.manifest, loader)
            .with_memory_budget(Some(budget))
            .with_policy(Some(policy.clone()));
        let mut conn = Connection::new(&registry, None);
        let resp = conn.handle(
            &Json::parse(r#"{"op":"load","auto":true,"family":"gpt2like","tier":"t0"}"#)?,
        );
        println!(
            "{label} budget ({budget} B): auto-load -> {}",
            resp.get("model")?.as_str()?
        );
        let score = conn.handle(&Json::parse(r#"{"op":"score","tokens":[1,5,9,12,3]}"#)?);
        println!("  score ce {:.4}", score.get("ce")?.as_f64()?);
    }
    println!("\n(no dominated config can ever be picked: the policy stores only the frontier)");
    Ok(())
}
