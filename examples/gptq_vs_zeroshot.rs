//! One-shot GPTQ vs zero-shot quantization (the paper's §7 comparison).
//!
//! Reproduces the *mechanism* behind Table 1 and Figure 5 at layer level:
//! quantize a trained projection matrix with (a) zero-shot RTN, (b) GPTQ,
//! at 2/3/4 bits × several block sizes, and compare layerwise
//! reconstruction error against real calibration activations. GPTQ with
//! blocking should dominate zero-shot 3-bit — the paper's argument that
//! one-shot methods are the road below 4-bit.
//!
//! Run: `cargo run --release --example gptq_vs_zeroshot`
//! (pure Rust; uses a synthetic trained-like weight, no artifacts needed)

use kbitscale::gptq::{gptq_quantize, reconstruction_error, rtn_quantize, GptqConfig};
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::QuantSpec;
use kbitscale::tensor::Tensor;
use kbitscale::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (in_dim, out_dim, samples) = (128usize, 64usize, 256usize);
    let mut rng = Rng::new(7);

    // Weight with outlier input dims (the hard case for low-bit RTN).
    let mut w = vec![0.0f32; in_dim * out_dim];
    rng.fill_normal(&mut w, 0.05);
    for r in [3usize, 40, 77] {
        for c in 0..out_dim {
            w[r * out_dim + c] *= 15.0;
        }
    }
    let w = Tensor::new(vec![in_dim, out_dim], w);

    // Correlated calibration activations (what GPTQ's Hessian feeds on).
    let mut x = vec![0.0f32; samples * in_dim];
    for s in 0..samples {
        let base = rng.normal() as f32;
        for i in 0..in_dim {
            x[s * in_dim + i] = 0.6 * base + 0.4 * rng.normal() as f32;
        }
    }
    let x = Tensor::new(vec![samples, in_dim], x);

    println!("layerwise relative reconstruction error ||x(w - wq)||^2 / ||xw||^2\n");
    println!(
        "{:<8} {:<10} {:>14} {:>14} {:>9}",
        "bits", "block", "zero-shot RTN", "one-shot GPTQ", "GPTQ win"
    );
    for bits in [4usize, 3, 2] {
        for block in [None, Some(256), Some(64)] {
            let spec = QuantSpec::new(DataType::Int, bits, block);
            let label = block.map(|b| b.to_string()).unwrap_or_else(|| "none".into());
            let r = rtn_quantize(&w, &spec)?;
            let g = gptq_quantize(&w, &x, &spec, &GptqConfig::default())?;
            let er = reconstruction_error(&w, &r, &x)?;
            let eg = reconstruction_error(&w, &g, &x)?;
            println!(
                "{:<8} {:<10} {:>14.6} {:>14.6} {:>8.1}x",
                bits,
                label,
                er,
                eg,
                er / eg.max(1e-12)
            );
        }
    }
    println!("\nPaper Table 1's shape: GPTQ needs blocking to win at 2-bit, and");
    println!("one-shot beats zero-shot at every precision — run `cargo bench");
    println!("--bench fig5_table1_gptq` for the full model-level comparison.");
    Ok(())
}
