//! The latency story (§2.1): fused k-bit dequant-matmul via the Pallas
//! AOT kernels, measured from Rust through PJRT.
//!
//! Loads the three standalone kernel artifacts — f32 matmul baseline,
//! u8-index blockwise dequant-matmul, and the genuinely packed 4-bit
//! variant — quantizes a weight on the Rust side, checks numerics against
//! the CPU reference, and reports wall-clock plus the **bits-loaded
//! ratio** the paper's latency claim is proportional to (the CPU plugin
//! can't show HBM-bound TPU speedups; the analytic VMEM/MXU estimates
//! live in DESIGN.md §7 / EXPERIMENTS.md §Perf).
//!
//! Run: `make artifacts && cargo run --release --example fused_kernel_latency`

use kbitscale::models::manifest::Manifest;
use kbitscale::quant::codebook::{Codebook, DataType};
use kbitscale::quant::packing::pack4_rows;
use kbitscale::runtime::{lit_f32, lit_u8, to_vec_f32, Runtime};
use kbitscale::tensor::Tensor;
use kbitscale::util::progress::bench_best;
use kbitscale::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let km = &manifest.kernels;
    let (m, k, n, qb) = (km.m, km.k, km.n, km.qblock);
    let rt = Runtime::cpu()?;

    let mut rng = Rng::new(3);
    let mut x = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut w, 0.05);

    // Column-block quantization in the kernel layout (blocks along K).
    let cb = Codebook::build(DataType::Fp, 4, None)?;
    let mut idx = vec![0u8; k * n];
    let mut amax = vec![0.0f32; (k / qb) * n];
    for c in 0..n {
        for b in 0..k / qb {
            let mut a = 0.0f32;
            for r in b * qb..(b + 1) * qb {
                a = a.max(w[r * n + c].abs());
            }
            let a = if a == 0.0 { 1.0 } else { a };
            amax[b * n + c] = a;
            for r in b * qb..(b + 1) * qb {
                idx[r * n + c] = cb.assign(w[r * n + c] / a);
            }
        }
    }
    let packed = pack4_rows(&idx, k, n)?;

    // Literals.
    let x_t = Tensor::new(vec![m, k], x.clone());
    let w_t = Tensor::new(vec![k, n], w.clone());
    let amax_t = Tensor::new(vec![k / qb, n], amax.clone());
    let cb_t = Tensor::new(vec![km.codebook_pad], cb.padded_values(km.codebook_pad));

    let f32_exe = rt.load(&manifest.hlo_path(&km.f32_hlo))?;
    let u8_exe = rt.load(&manifest.hlo_path(&km.u8_hlo))?;
    let p4_exe = rt.load(&manifest.hlo_path(&km.packed4_hlo))?;

    // Numerics check: fused u8 path == Rust-side dequant then matmul.
    let args = vec![lit_f32(&x_t)?, lit_u8(&[k, n], &idx)?, lit_f32(&amax_t)?, lit_f32(&cb_t)?];
    let fused = to_vec_f32(&rt.execute(&u8_exe, &args)?[0])?;
    let mut want = vec![0.0f32; m * n];
    for i in 0..m {
        for c in 0..n {
            let mut acc = 0.0f64;
            for r in 0..k {
                let dq = cb.value(idx[r * n + c]) * amax[(r / qb) * n + c];
                acc += x[i * k + r] as f64 * dq as f64;
            }
            want[i * n + c] = acc as f32;
        }
    }
    let max_err = fused
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("fused-u8 vs reference max |err| = {max_err:.2e} (tolerance 1e-2)");
    anyhow::ensure!(max_err < 1e-2, "fused kernel numerics diverged");

    // Latency (CPU wall-clock; the interesting column is bits loaded).
    let reps = 20;
    let t_f32 = bench_best(3, reps, || {
        let args = vec![lit_f32(&x_t).unwrap(), lit_f32(&w_t).unwrap()];
        rt.execute(&f32_exe, &args).unwrap();
    });
    let t_u8 = bench_best(3, reps, || {
        let args = vec![
            lit_f32(&x_t).unwrap(),
            lit_u8(&[k, n], &idx).unwrap(),
            lit_f32(&amax_t).unwrap(),
            lit_f32(&cb_t).unwrap(),
        ];
        rt.execute(&u8_exe, &args).unwrap();
    });
    let t_p4 = bench_best(3, reps, || {
        let args = vec![
            lit_f32(&x_t).unwrap(),
            lit_u8(&[k / 2, n], &packed).unwrap(),
            lit_f32(&amax_t).unwrap(),
            lit_f32(&cb_t).unwrap(),
        ];
        rt.execute(&p4_exe, &args).unwrap();
    });

    let w_bits_f32 = (k * n * 32) as f64;
    let w_bits_u8 = (k * n * 8 + (k / qb) * n * 32) as f64;
    let w_bits_p4 = (k * n * 4 + (k / qb) * n * 32) as f64;
    println!("\n{m}x{k}x{n} matmul, weight-quant block {qb}:");
    println!("{:<22} {:>10} {:>16} {:>16}", "variant", "wall (ms)", "weight bits", "bits-loaded ratio");
    println!("{:<22} {:>10.3} {:>16.2e} {:>16.2}", "f32 baseline", t_f32 * 1e3, w_bits_f32, 1.0);
    println!("{:<22} {:>10.3} {:>16.2e} {:>16.2}", "4-bit idx as u8", t_u8 * 1e3, w_bits_u8, w_bits_f32 / w_bits_u8);
    println!("{:<22} {:>10.3} {:>16.2e} {:>16.2}", "4-bit packed", t_p4 * 1e3, w_bits_p4, w_bits_f32 / w_bits_p4);
    println!("\nOn memory-bound hardware latency tracks the bits-loaded column");
    println!("(paper: 4.46x at 3-bit on OPT-175B); the CPU interpret path only");
    println!("validates numerics and the storage layout.");
    Ok(())
}
