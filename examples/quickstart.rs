//! Quickstart: the library in ~60 lines.
//!
//! Quantizes a weight tensor with each of the paper's four data types at
//! 4-bit / block-64, reports round-trip error and bits/parameter, then
//! shows the paper's central trade-off on raw quantization error.
//!
//! Run: `cargo run --release --example quickstart`
//! (no artifacts needed — this exercises the pure-Rust quant core).

use kbitscale::quant::codebook::DataType;
use kbitscale::quant::{bits_per_param, blockwise, QuantSpec};
use kbitscale::util::rng::Rng;

fn main() {
    // A synthetic "weight matrix": near-normal with a few outliers, the
    // shape real transformer projections have.
    let mut rng = Rng::new(42);
    let mut w = vec![0.0f32; 64 * 256];
    rng.fill_normal(&mut w, 0.02);
    for i in 0..8 {
        w[i * 1000] *= 20.0; // emergent-outlier-style heavy entries
    }

    println!("quantizing a 64x256 weight with each data type (4-bit, block 64):\n");
    println!("{:<10} {:>12} {:>12}", "dtype", "rms error", "bits/param");
    for dtype in DataType::ALL {
        let spec = QuantSpec::new(dtype, 4, Some(64));
        let rms = blockwise::rms_error(&w, &spec);
        println!("{:<10} {:>12.6} {:>12.2}", dtype.name(), rms, bits_per_param(&spec));
    }

    println!("\nblock size sweep (4-bit fp) — small blocks confine the outliers:\n");
    println!("{:<12} {:>12} {:>12}", "block", "rms error", "bits/param");
    for block in [None, Some(1024), Some(256), Some(64), Some(16)] {
        let spec = QuantSpec::new(DataType::Fp, 4, block);
        let label = block.map(|b| b.to_string()).unwrap_or_else(|| "tensor".into());
        println!(
            "{:<12} {:>12.6} {:>12.2}",
            label,
            blockwise::rms_error(&w, &spec),
            bits_per_param(&spec)
        );
    }

    println!("\nprecision sweep (fp, block 64) — the bit-level trade-off:\n");
    println!("{:<8} {:>12} {:>12}", "bits", "rms error", "bits/param");
    for bits in [8usize, 6, 5, 4, 3] {
        let spec = QuantSpec::new(DataType::Fp, bits, Some(64));
        println!(
            "{:<8} {:>12.6} {:>12.2}",
            bits,
            blockwise::rms_error(&w, &spec),
            bits_per_param(&spec)
        );
    }
    println!("\nError halves per bit while storage shrinks linearly — the");
    println!("accuracy-vs-bits race behind the paper's 4-bit optimum. Run the");
    println!("`scaling_laws` example for the full model-level version.");
}
