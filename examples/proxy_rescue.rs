//! Proxy quantization rescuing 3-bit outlier-family models (§3, Figure 4).
//!
//! Loads an OPT-like (outlier-injected) and a GPT-2-like (stable)
//! checkpoint, quantizes both at 3-bit with and without proxy
//! quantization, and shows that (a) the outlier family degrades far more
//! at 3-bit, (b) proxy quantization largely repairs it, (c) the stable
//! family gains little — and that even repaired 3-bit loses to plain
//! 4-bit at matched bits (the paper's headline negative result for
//! outlier-dependent quantization).
//!
//! Run: `make artifacts && cargo run --release --example proxy_rescue`
//! (trains the two t1 checkpoints on first use)

use kbitscale::bench_support::BenchEnv;
use kbitscale::eval::Evaluator;
use kbitscale::quant::codebook::DataType;
use kbitscale::quant::{bits_per_param, quantize_checkpoint, QuantSpec};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open()?;
    let tier_name = "t1";
    env.ensure_trained(&["optlike", "gpt2like"], &[tier_name.to_string()])?;
    let tier = env.ctx.manifest.tier(tier_name)?;

    let specs = [
        ("16-bit baseline", QuantSpec::baseline16()),
        ("4-bit fp b64", QuantSpec::new(DataType::Fp, 4, Some(64))),
        ("3-bit fp b64", QuantSpec::new(DataType::Fp, 3, Some(64))),
        ("3-bit + proxy 2%", QuantSpec::new(DataType::Fp, 3, Some(64)).with_proxy(0.02)),
        ("4-bit + proxy 2%", QuantSpec::new(DataType::Fp, 4, Some(64)).with_proxy(0.02)),
    ];

    for family in ["optlike", "gpt2like"] {
        let id = kbitscale::models::ModelId::new(family, tier_name);
        let (params, meta) = env.checkpoints.load(&id)?;
        let ev = Evaluator::new(&env.ctx.rt, &env.ctx.manifest, tier)?;
        println!("\n== {family}/{tier_name} (trained loss {:.3}) ==", meta.final_loss);
        println!("{:<20} {:>10} {:>9} {:>12}", "config", "ce", "ppl", "bits/param");
        for (label, spec) in &specs {
            let q = quantize_checkpoint(&params, &tier.quantized_params, spec);
            let plits = ev.param_literals(&q)?;
            let (ce, ppl, _) = ev.perplexity(&plits, &env.ctx.corpus, 32)?;
            println!("{label:<20} {ce:>10.4} {ppl:>9.2} {:>12.2}", bits_per_param(spec));
        }
    }
    println!("\nExpected shape (paper Fig. 4): the optlike 3-bit row collapses,");
    println!("proxy repairs most of it, gpt2like barely moves — and 4-bit");
    println!("plain still beats 3-bit+proxy at fewer total bits.");
    Ok(())
}
